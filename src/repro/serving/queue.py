"""Thread-safe admission queue with bounded depth, backpressure, and
expired-request load-shedding.

The admission queue is the single entry point for ALL work reaching the async
AIDW worker — query requests AND dataset-update barriers share one FIFO, which
is what serializes churn against query batches (``serving/server.py``).

Policies (all enforced here, not in callers):

* **bounded depth** — at most ``max_depth`` items are admitted.  A full queue
  exerts backpressure: ``put(block=True)`` waits (optionally up to
  ``timeout``), ``put(block=False)`` raises :class:`AdmissionQueueFull`
  immediately.  Rejection is loud, never silent.
* **load-shedding** — an item whose ``deadline`` (absolute seconds on the
  queue's ``clock``) has already passed is refused admission: serving it
  would burn a batch slot on an answer the client has already abandoned.
  ``put`` returns ``False`` and the item is NOT enqueued; callers mark the
  request shed.  (The scheduler applies the same check again at dispatch
  time for requests that expired while queued.)
* **FIFO** — admitted items pop in arrival order; the deadline-aware
  coalescer downstream decides batch boundaries, never reordering.

Items are duck-typed: anything with an optional ``deadline`` attribute
queues (``None`` = no deadline, never shed).
"""

from __future__ import annotations

import threading
import time
from collections import deque

__all__ = ["AdmissionQueue", "AdmissionQueueClosed", "AdmissionQueueFull",
           "validate_queries"]


def validate_queries(queries_xy):
    """Boundary check shared by every admission surface (server ``submit``,
    cluster router): returns the ndarray or raises ValueError.  One copy,
    so the server and the router can never drift on what a well-formed
    query batch is (a router/server disagreement would misread bad input
    as host death)."""
    import numpy as np

    q = np.asarray(queries_xy)
    if q.ndim != 2 or q.shape[1] != 2 or q.shape[0] == 0 \
            or not np.issubdtype(q.dtype, np.floating):
        raise ValueError(
            f"queries_xy must be a non-empty float (n, 2) array, got "
            f"shape {q.shape} dtype {q.dtype}")
    return q


class AdmissionQueueFull(RuntimeError):
    """Bounded admission queue is at ``max_depth`` (backpressure signal)."""


class AdmissionQueueClosed(RuntimeError):
    """``put`` after ``close()`` — the worker is shutting down."""


class AdmissionQueue:
    """``clock`` is the DEADLINE clock (injectable for deterministic expiry
    tests); blocking-wait timeouts always run on real ``time.monotonic`` —
    a frozen test clock must bound waits, not disable them."""

    def __init__(self, max_depth: int = 1024, *, clock=time.monotonic):
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.max_depth = int(max_depth)
        self.clock = clock
        self._items: deque = deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._closed = False
        self.counters = {"admitted": 0, "shed_expired": 0, "rejected_full": 0}

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    @staticmethod
    def expired(item, now: float) -> bool:
        deadline = getattr(item, "deadline", None)
        return deadline is not None and now >= deadline

    def put(self, item, *, block: bool = True,
            timeout: float | None = None) -> bool:
        """Admit ``item``.  Returns True (admitted) or False (shed: already
        expired on arrival).  Raises :class:`AdmissionQueueFull` when the
        depth bound holds after blocking (or immediately if ``block=False``).
        """
        with self._not_full:
            if self._closed:
                raise AdmissionQueueClosed("admission queue is closed")
            if self.expired(item, self.clock()):
                self.counters["shed_expired"] += 1
                return False
            if len(self._items) >= self.max_depth:
                if not block:
                    self.counters["rejected_full"] += 1
                    raise AdmissionQueueFull(
                        f"admission queue at max_depth={self.max_depth}")
                deadline = None if timeout is None \
                    else time.monotonic() + timeout
                while len(self._items) >= self.max_depth and not self._closed:
                    remaining = None if deadline is None \
                        else deadline - time.monotonic()
                    if remaining is not None and remaining <= 0:
                        self.counters["rejected_full"] += 1
                        raise AdmissionQueueFull(
                            f"admission queue at max_depth={self.max_depth} "
                            f"after {timeout}s")
                    self._not_full.wait(remaining)
                if self._closed:
                    raise AdmissionQueueClosed("admission queue is closed")
                # re-check expiry: the wait may have outlived the deadline
                if self.expired(item, self.clock()):
                    self.counters["shed_expired"] += 1
                    return False
            self._items.append(item)
            self.counters["admitted"] += 1
            self._not_empty.notify()
            return True

    def get(self, timeout: float | None = None):
        """Pop the oldest item (FIFO); ``None`` on timeout or when closed and
        drained."""
        with self._not_empty:
            deadline = None if timeout is None else time.monotonic() + timeout
            while not self._items:
                if self._closed:
                    return None
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return None
                self._not_empty.wait(remaining)
            item = self._items.popleft()
            self._not_full.notify()
            return item

    def drain(self) -> list:
        """Pop everything currently queued (non-blocking)."""
        with self._lock:
            items = list(self._items)
            self._items.clear()
            self._not_full.notify_all()
            return items

    def close(self) -> None:
        """Refuse new work; blocked getters/putters wake up."""
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed
