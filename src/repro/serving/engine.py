"""Batched serving engine: slot-based continuous batching over prefill/decode.

A fixed pool of ``batch_size`` slots decodes in lockstep (the jitted decode
step is one token for the whole pool).  When a slot finishes (EOS/max_tokens)
it is refilled from the request queue by re-prefilling JUST that slot's
sequence and splicing its cache into the pool — the classic
continuous-batching slot swap, expressed with pure-functional cache updates.

Simplifications vs. a production stack (documented): synchronized position
counter per slot via per-slot start offsets is folded into the attention
validity mask; prompts within one engine share a maximum prompt length
(length-classed queues).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.config import ModelConfig


@dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 32
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_size: int,
                 max_len: int, eos_id: int | None = None):
        assert not cfg.enc_dec, "engine demo targets decoder-only archs"
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        self._prefill = jax.jit(api.prefill_fn(cfg))
        self._decode = jax.jit(api.decode_fn(cfg), donate_argnums=(1,))
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens": 0}

    # -- internal ------------------------------------------------------------

    # batch axis per cache entry (for slot splicing)
    _CACHE_BATCH_AXIS = {"k": 1, "v": 1, "ck": 1, "cv": 1,
                         "conv": 1, "ssm": 1, "valid": 0}

    def _prefill_batch(self, prompts: np.ndarray):
        """prompts (B, S0) -> (next_tokens (B,), cache grown to max_len)."""
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        self.stats["prefills"] += 1
        cache = dict(cache)
        s0 = prompts.shape[1]
        for k in ("k", "v"):
            if k in cache:
                pad = [(0, 0)] * cache[k].ndim
                pad[2] = (0, self.max_len - s0)
                cache[k] = jnp.pad(cache[k], pad)
        if self.cfg.family != "ssm":
            # per-slot validity: only the prompt prefix is populated
            valid = jnp.zeros((prompts.shape[0], self.max_len), bool)
            cache["valid"] = valid.at[:, :s0].set(True)
        return np.asarray(jnp.argmax(logits, -1)), cache

    def _splice_slot(self, cache: dict, fresh: dict, i: int) -> dict:
        """Copy slot ``i`` of ``fresh`` (a 1-sequence cache) into ``cache``."""
        out = dict(cache)
        for k, ax in self._CACHE_BATCH_AXIS.items():
            if k in out:
                idx = [slice(None)] * out[k].ndim
                idx[ax] = slice(i, i + 1)
                out[k] = out[k].at[tuple(idx)].set(fresh[k])
        return out

    # -- public --------------------------------------------------------------

    def run(self, requests: list[Request]) -> dict:
        """Serve all requests; returns throughput stats."""
        queue = list(requests)
        assert queue and all(len(r.prompt) == len(queue[0].prompt) for r in queue), \
            "engine demo uses one prompt-length class"
        s0 = len(queue[0].prompt)

        t_start = time.perf_counter()
        while queue:
            queue = self._run_pool(queue, s0)
        dt = time.perf_counter() - t_start
        self.stats["wall_s"] = dt
        self.stats["tokens_per_s"] = self.stats["tokens"] / max(dt, 1e-9)
        return dict(self.stats)

    def _run_pool(self, queue: list[Request], s0: int) -> list[Request]:
        """One pool lifetime: fill slots, decode until max_len, return leftovers.

        (Requests still active when the position counter exhausts the cache
        are re-queued and continue in the next pool — 'pool recycling'.)"""
        active: list[Request | None] = [None] * self.B
        first = [queue.pop(0) if queue else None for _ in range(self.B)]
        prompts = np.stack([
            (r.prompt if r is not None else np.zeros(s0, np.int32))
            for r in first])
        next_tok, cache = self._prefill_batch(prompts)
        for i, r in enumerate(first):
            if r is not None:
                r.out_tokens.append(int(next_tok[i]))
                self.stats["tokens"] += 1
                self._finish(r)
                active[i] = None if r.done else r

        pos = s0
        tokens = next_tok[:, None].astype(np.int32)
        while any(a is not None for a in active) or queue:
            if pos >= self.max_len:
                # recycle: unfinished actives go back to the queue head
                return [r for r in active if r is not None and not r.done] + queue
            logits, cache = self._decode(
                self.params, cache,
                {"tokens": jnp.asarray(tokens), "pos": jnp.int32(pos)})
            self.stats["decode_steps"] += 1
            nxt = np.array(jnp.argmax(logits, -1))  # writable copy (slot swap)
            pos += 1
            for i, r in enumerate(active):
                if r is None:
                    continue
                tok = int(nxt[i])
                r.out_tokens.append(tok)
                self.stats["tokens"] += 1
                if self._finish(r, tok):
                    active[i] = queue.pop(0) if queue else None
                    if active[i] is not None:
                        # slot swap: re-prefill just this sequence, splice in
                        lg, c1 = self._prefill_batch(active[i].prompt[None, :])
                        cache = self._splice_slot(cache, c1, i)
                        active[i].out_tokens.append(int(lg[0]))
                        self.stats["tokens"] += 1
                        self._finish(active[i])
                        if active[i].done:
                            active[i] = None
                        else:
                            nxt[i] = active[i].out_tokens[-1]
            tokens = nxt[:, None].astype(np.int32)
        return queue

    def _finish(self, r: Request, tok: int | None = None) -> bool:
        if len(r.out_tokens) >= r.max_new_tokens or \
                (tok is not None and tok == self.eos_id):
            r.done = True
        return r.done
