"""Batched serving engines.

Two workloads share this module:

* :class:`ServingEngine` — LM slot-based continuous batching over
  prefill/decode.  A fixed pool of ``batch_size`` slots decodes in lockstep
  (the jitted decode step is one token for the whole pool).  When a slot
  finishes (EOS/max_tokens) it is refilled from the request queue by
  re-prefilling JUST that slot's sequence and splicing its cache into the
  pool — the classic continuous-batching slot swap, expressed with
  pure-functional cache updates.

* :class:`AidwEngine` — spatial-interpolation serving over a persistent
  :class:`repro.core.session.InterpolationSession`.  The Stage-1 grid build
  is amortized across the session; incoming requests are coalesced FIFO into
  microbatches of at most ``max_batch`` queries, and the session's
  power-of-two bucketing keeps a stream of odd-sized microbatches on one
  compiled executable.  With ``mesh=`` the session serves each microbatch
  across the whole mesh (queries partitioned over every axis; the plan
  replicated, brute-force ring-sharded, or grid-aware ring-sharded with
  ``layout='grid_ring'`` — per-slab CSR tables + halo, the O(window)
  Stage-1 at O(m/P) memory), and ``update_dataset(inserts=/deletes=)``
  refreshes a high-churn dataset incrementally without a Stage-1 rebuild
  (grid-ring: patching only the owning slabs' tables).

:class:`AidwEngine` is the SYNCHRONOUS drive mode of the serving subsystem:
the caller hands it a request list per step and it drives the shared
deadline-aware coalescer (``repro.serving.scheduler``) to completion inline.
The asynchronous drive mode — admission-queue thread, backpressure,
deadline shedding, serialized dataset updates — is
:class:`repro.serving.server.AsyncAidwServer` over the SAME scheduler, so
batch composition (and therefore results) match between the two modes.

Simplifications vs. a production stack (documented): synchronized position
counter per slot via per-slot start offsets is folded into the attention
validity mask; prompts within one engine share a maximum prompt length
(length-classed queues).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.config import ModelConfig


@dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 32
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_size: int,
                 max_len: int, eos_id: int | None = None):
        assert not cfg.enc_dec, "engine demo targets decoder-only archs"
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        self._prefill = jax.jit(api.prefill_fn(cfg))
        self._decode = jax.jit(api.decode_fn(cfg), donate_argnums=(1,))
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens": 0}

    # -- internal ------------------------------------------------------------

    # batch axis per cache entry (for slot splicing)
    _CACHE_BATCH_AXIS = {"k": 1, "v": 1, "ck": 1, "cv": 1,
                         "conv": 1, "ssm": 1, "valid": 0}

    def _prefill_batch(self, prompts: np.ndarray):
        """prompts (B, S0) -> (next_tokens (B,), cache grown to max_len)."""
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        self.stats["prefills"] += 1
        cache = dict(cache)
        s0 = prompts.shape[1]
        for k in ("k", "v"):
            if k in cache:
                pad = [(0, 0)] * cache[k].ndim
                pad[2] = (0, self.max_len - s0)
                cache[k] = jnp.pad(cache[k], pad)
        if self.cfg.family != "ssm":
            # per-slot validity: only the prompt prefix is populated
            valid = jnp.zeros((prompts.shape[0], self.max_len), bool)
            cache["valid"] = valid.at[:, :s0].set(True)
        return np.asarray(jnp.argmax(logits, -1)), cache

    def _splice_slot(self, cache: dict, fresh: dict, i: int) -> dict:
        """Copy slot ``i`` of ``fresh`` (a 1-sequence cache) into ``cache``."""
        out = dict(cache)
        for k, ax in self._CACHE_BATCH_AXIS.items():
            if k in out:
                idx = [slice(None)] * out[k].ndim
                idx[ax] = slice(i, i + 1)
                out[k] = out[k].at[tuple(idx)].set(fresh[k])
        return out

    # -- public --------------------------------------------------------------

    def run(self, requests: list[Request]) -> dict:
        """Serve all requests; returns throughput stats."""
        queue = list(requests)
        assert queue and all(len(r.prompt) == len(queue[0].prompt) for r in queue), \
            "engine demo uses one prompt-length class"
        s0 = len(queue[0].prompt)

        t_start = time.perf_counter()
        while queue:
            queue = self._run_pool(queue, s0)
        dt = time.perf_counter() - t_start
        self.stats["wall_s"] = dt
        self.stats["tokens_per_s"] = self.stats["tokens"] / max(dt, 1e-9)
        return dict(self.stats)

    def _run_pool(self, queue: list[Request], s0: int) -> list[Request]:
        """One pool lifetime: fill slots, decode until max_len, return leftovers.

        (Requests still active when the position counter exhausts the cache
        are re-queued and continue in the next pool — 'pool recycling'.)"""
        active: list[Request | None] = [None] * self.B
        first = [queue.pop(0) if queue else None for _ in range(self.B)]
        prompts = np.stack([
            (r.prompt if r is not None else np.zeros(s0, np.int32))
            for r in first])
        next_tok, cache = self._prefill_batch(prompts)
        for i, r in enumerate(first):
            if r is not None:
                r.out_tokens.append(int(next_tok[i]))
                self.stats["tokens"] += 1
                self._finish(r)
                active[i] = None if r.done else r

        pos = s0
        tokens = next_tok[:, None].astype(np.int32)
        while any(a is not None for a in active) or queue:
            if pos >= self.max_len:
                # recycle: unfinished actives go back to the queue head
                return [r for r in active if r is not None and not r.done] + queue
            logits, cache = self._decode(
                self.params, cache,
                {"tokens": jnp.asarray(tokens), "pos": jnp.int32(pos)})
            self.stats["decode_steps"] += 1
            nxt = np.array(jnp.argmax(logits, -1))  # writable copy (slot swap)
            pos += 1
            for i, r in enumerate(active):
                if r is None:
                    continue
                tok = int(nxt[i])
                r.out_tokens.append(tok)
                self.stats["tokens"] += 1
                if self._finish(r, tok):
                    active[i] = queue.pop(0) if queue else None
                    if active[i] is not None:
                        # slot swap: re-prefill just this sequence, splice in
                        lg, c1 = self._prefill_batch(active[i].prompt[None, :])
                        cache = self._splice_slot(cache, c1, i)
                        active[i].out_tokens.append(int(lg[0]))
                        self.stats["tokens"] += 1
                        self._finish(active[i])
                        if active[i].done:
                            active[i] = None
                        else:
                            nxt[i] = active[i].out_tokens[-1]
            tokens = nxt[:, None].astype(np.int32)
        return queue

    def _finish(self, r: Request, tok: int | None = None) -> bool:
        if len(r.out_tokens) >= r.max_new_tokens or \
                (tok is not None and tok == self.eos_id):
            r.done = True
        return r.done


# ---------------------------------------------------------------------------
# AIDW interpolation serving
# ---------------------------------------------------------------------------


@dataclass
class InterpolationRequest:
    """One client request: ``n`` query points, optionally deadline-bound.

    ``deadline`` is ABSOLUTE seconds on the serving clock
    (``time.monotonic`` unless the engine/server was built with an injected
    clock); ``None`` means never shed.  Terminal states are
    ``status == "done"`` (``values``/``overflow`` populated) and
    ``status == "shed"`` (deadline expired before dispatch; never served).
    ``overflow`` counts THIS request's queries whose kNN candidate window
    overflowed — propagated per-request from the batch's per-query mask, not
    summed engine-wide.  ``epoch`` is the dataset epoch the request was
    SERVED under (stamped at dispatch by the async server / cluster hosts;
    ``None`` on the epoch-less synchronous engine).

    ``trace_id``/``parent_span`` are the request's trace context
    (``repro.obs``): set by the client (or propagated across the rpc
    control plane by a fleet router) to join an existing trace, or stamped
    by the server's sampler at admission.  ``None`` = untraced — every
    span call site is then a no-op.
    """

    uid: int
    queries_xy: np.ndarray          # (n, 2)
    values: np.ndarray | None = None
    done: bool = False
    deadline: float | None = None   # absolute clock seconds; None = no SLO
    status: str = "pending"         # pending | queued | done | shed
    overflow: int = 0               # this request's overflowed queries
    zero_weight: int = 0            # queries that hit the f32 weight-sum
                                    # underflow sentinel (anomaly class)
    epoch: int | None = None        # dataset epoch served under (async only)
    t_submit: float | None = None   # admission timestamp (serving clock)
    t_dispatch: float | None = None
    t_done: float | None = None
    trace_id: str | None = None     # obs trace context (None = untraced)
    parent_span: str | None = None


class AidwEngine:
    """Microbatched AIDW serving over one InterpolationSession (synchronous
    drive mode).

    Requests are coalesced in arrival order into batches of at most
    ``max_batch`` queries (a request larger than ``max_batch`` forms its own
    batch), interpolated with ONE ``session.query`` per coalesced batch, and
    scattered back to their requests — so p requests of n queries each cost
    ceil(p*n / max_batch) jitted launches instead of p, and zero Stage-1
    rebuilds.  Coalescing, deadline handling, and result scattering live in
    ``repro.serving.scheduler`` (shared with the async server): requests
    with a ``deadline`` close batches early under deadline pressure and are
    shed (``status == "shed"``) once expired; requests without deadlines
    reproduce plain FIFO coalescing byte-for-byte.

    ``run`` returns a PER-CALL report (wall time, throughput, and this
    call's counts); the cumulative counters accumulate on ``self.stats`` and
    the latency histograms on ``self.telemetry``.
    """

    def __init__(self, points_xyz, cfg=None, *, max_batch: int = 8192,
                 query_domain=None, min_bucket: int = 64, mesh=None,
                 layout: str = "replicated", slack_s: float = 0.0,
                 ring_cap: int = 256, clock=time.monotonic, tracer=None,
                 wall=time.time):
        from repro.core import AidwConfig
        from repro.core.session import InterpolationSession
        from repro.obs import Registry

        from . import scheduler as S
        from .telemetry import Telemetry

        # ONE registry for the whole engine: the session's stage walls and
        # the telemetry's latency histograms land in the same namespace, so
        # report()/Prometheus read one unified surface
        self.registry = Registry()
        self.tracer = tracer
        self.session = InterpolationSession(
            points_xyz, cfg or AidwConfig(), query_domain=query_domain,
            min_bucket=min_bucket, mesh=mesh, layout=layout,
            ring_cap=ring_cap, tracer=tracer, registry=self.registry)
        self.max_batch = int(max_batch)
        self.clock = clock
        # keyed on (query bucket, dataset bucket): estimates stay calibrated
        # across resizing delta updates (update_dataset refreshes n_points)
        self.estimator = S.ExecuteTimeModel(
            min_bucket=min_bucket, n_points=self.session.plan.n_points)
        self.coalescer = S.DeadlineCoalescer(
            self.max_batch, self.estimator, clock=clock, slack_s=slack_s)
        self.telemetry = Telemetry(clock=clock, wall=wall,
                                   registry=self.registry)
        self.stats = {"requests": 0, "batches": 0, "queries": 0,
                      "overflow": 0, "shed": 0}

    def update_dataset(self, points_xyz=None, *, inserts=None, deletes=None,
                       deltas=None) -> None:
        """Refresh the served dataset: full (one Stage-1 rebuild, executables
        kept) or incremental (``inserts``/``deletes``/``deltas`` patch the
        CSR table; zero Stage-1 rebuilds)."""
        self.session.update(points_xyz, inserts=inserts, deletes=deletes,
                            deltas=deltas)
        self.estimator.n_points = self.session.plan.n_points
        self.telemetry.record_update()

    def run(self, requests: list[InterpolationRequest]) -> dict:
        """Serve all requests; returns the PER-CALL report.

        The report's ``requests``/``batches``/``queries``/``overflow``/
        ``shed`` count THIS call only; ``wall_s``/``queries_per_s`` time it.
        Cumulative counters (across all ``run`` calls) live on
        ``self.stats`` and never carry per-call timing keys.
        """
        from . import scheduler as S

        t0 = time.perf_counter()
        now = self.clock()
        for r in requests:
            if r.t_submit is None:
                r.t_submit = now
            if r.trace_id is None and self.tracer is not None:
                r.trace_id = self.tracer.new_trace()   # sampling at the root
            self.telemetry.record_submit(r)
        # form batches INCREMENTALLY with a fresh clock per batch (exactly
        # like the async worker): a request whose deadline expires while
        # earlier groups execute is shed at dispatch time, not served late
        pending = deque(requests)
        served = batches = overflow = shed_n = 0
        while pending:
            group, shed = self.coalescer.next_batch(pending)
            for r in shed:
                self.telemetry.record_shed(r)
            shed_n += len(shed)
            if not group:
                if pending and not shed:     # barrier item: reject, don't spin
                    raise ValueError(
                        f"run() takes query requests only, got "
                        f"{type(pending[0]).__name__}")
                continue
            res = S.dispatch_batch(
                self.session, group, estimator=self.estimator,
                telemetry=self.telemetry, clock=self.clock,
                tracer=self.tracer)
            batches += 1
            served += sum(r.queries_xy.shape[0] for r in group)
            overflow += res.overflow
        report = {
            "requests": len(requests), "batches": batches,
            "queries": served, "overflow": overflow, "shed": shed_n,
        }
        for k, v in report.items():
            self.stats[k] += v
        dt = time.perf_counter() - t0
        report["wall_s"] = dt
        report["queries_per_s"] = served / max(dt, 1e-9)
        return report
