"""AidwCluster — the multi-host serving fleet front end.

Ties the cluster pieces together behind one server-like surface: an
:class:`~repro.serving.cluster.epochs.EpochCoordinator` totally orders
dataset updates, a :class:`~repro.serving.cluster.router.Router` spreads
query traffic over the live hosts, and per-host
:class:`~repro.serving.cluster.host.HostServer` elements (in-process, or
:class:`~repro.serving.cluster.rpc.RemoteHost` proxies for hosts in other
processes) do the serving.

Write path (the epoch-broadcast step of the protocol in
``cluster/epochs.py``): ``update_dataset`` assigns the next epoch and
enqueues the update on EVERY live host while holding the broadcast lock —
pinning the update's position in each host's FIFO relative to all queries
routed before/after — then releases the lock and waits for the fleet to
apply.  Concurrent ``update_dataset`` calls therefore serialize into one
total epoch order but their applications overlap across hosts.  A host
that fails mid-broadcast or mid-wait is drained (its queries resubmit to
survivors); the update succeeds if at least one live host applied it.
"""

from __future__ import annotations

import threading
import time

from .epochs import EpochCoordinator
from .host import HostServer
from .router import RoutedRequest, Router
from .telemetry import merge_reports

__all__ = ["AidwCluster"]


class AidwCluster:
    """N-host AIDW serving fleet behind one submit/update/flush surface.

    Either hand it ``hosts=`` (pre-built :class:`HostServer`/``RemoteHost``
    elements — the process-backed deployment path) or let it build
    ``n_hosts`` in-process hosts over ``points_xyz``, each with its own
    ``AsyncAidwServer`` (every host serves a full dataset replica;
    ``host_kwargs`` pass through, e.g. ``max_batch=``/``query_domain=``/
    ``mesh=``).  ``policy`` and ``heartbeat_timeout_s`` configure the
    router.
    """

    def __init__(self, points_xyz=None, n_hosts: int = 2, cfg=None, *,
                 hosts=None, policy: str = "round_robin",
                 heartbeat_timeout_s: float = 60.0, clock=time.monotonic,
                 **host_kwargs):
        if hosts is None:
            if points_xyz is None:
                raise ValueError("need points_xyz to build in-process hosts")
            hosts = [HostServer(i, points_xyz, cfg, clock=clock,
                                **host_kwargs)
                     for i in range(int(n_hosts))]
        self.hosts = list(hosts)
        self.clock = clock
        self.coordinator = EpochCoordinator()
        self.router = Router(self.hosts, policy=policy, clock=clock,
                             heartbeat_timeout_s=heartbeat_timeout_s)
        self._bcast = threading.Lock()

    # -- query path ----------------------------------------------------------

    def submit(self, queries_xy, *,
               deadline_s: float | None = None) -> RoutedRequest:
        """Route one query batch to a live host (see :class:`Router`)."""
        return self.router.route(queries_xy, deadline_s=deadline_s)

    def result(self, req: RoutedRequest,
               timeout: float | None = None) -> RoutedRequest:
        """Block until ``req`` is terminal (follows it across host drains)."""
        return self.router.wait(req, timeout=timeout)

    # -- write path ----------------------------------------------------------

    def update_dataset(self, points_xyz=None, *, inserts=None, deletes=None,
                       deltas=None, timeout: float | None = None) -> int:
        """Epoch-ordered fleet-wide dataset update; returns the epoch.

        Broadcast-enqueues under the coordinator lock (total epoch order on
        every host's FIFO), waits for application outside it.  Hosts that
        fail are drained — including on TIMEOUT, deliberately: a timed-out
        wait withdraws the host's op, leaving an epoch gap, and a host
        missing an epoch must leave rotation (consistency over
        availability; the server's gap guard enforces the same thing).
        Raises only when NO host applied the update.
        """
        if deltas is not None:
            inserts, deletes = deltas
        # ONE deadline for the whole fleet wait — hosts apply concurrently,
        # so waiting them out sequentially must not multiply the bound by N
        deadline = None if timeout is None else time.monotonic() + timeout
        handles = {}
        with self._bcast:
            upd = self.coordinator.assign(points_xyz=points_xyz,
                                          inserts=inserts, deletes=deletes)
            for hid in self.router.live_hosts():
                host = self.router._hosts[hid]
                try:
                    handles[hid] = (host, host.submit_update(upd))
                except Exception:
                    self.router.drain(hid)
        applied = 0
        first_err: BaseException | None = None
        for hid, (host, handle) in handles.items():
            try:
                host.wait_update(
                    handle, timeout=None if deadline is None
                    else max(deadline - time.monotonic(), 0.0))
                applied += 1
            except BaseException as e:
                first_err = first_err or e
                self.router.drain(hid)
        if not applied:
            raise first_err if first_err is not None else \
                RuntimeError(f"epoch {upd.epoch}: no live host to update")
        return upd.epoch

    # -- fleet lifecycle -----------------------------------------------------

    @property
    def epoch(self) -> int:
        """Newest assigned epoch (hosts may still be applying it)."""
        return self.coordinator.epoch

    def flush(self, timeout: float | None = None) -> None:
        """Wait for every routed request to reach a terminal state.

        Host flushes run first (fast path: lets each worker drain its FIFO);
        a host that fails its flush is drained and its requests follow the
        router's resubmission path.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        for hid in self.router.live_hosts():
            rem = None if deadline is None \
                else max(deadline - time.monotonic(), 0.0)
            try:
                self.router._hosts[hid].flush(timeout=rem)
            except TimeoutError:
                # backlogged, not dead: flush is read-only, so slowness must
                # NOT drain the host (the router flush below reports the
                # timeout to the caller; the fleet stays intact for a retry)
                pass
            except Exception:
                self.router.drain(hid)
        self.router.flush(timeout=None if deadline is None
                          else max(deadline - time.monotonic(), 0.0))

    def report(self) -> dict:
        """Merged fleet report + per-host reports + routing counters."""
        host_reps = []
        for hid in self.router.live_hosts():
            try:
                host_reps.append(self.router._hosts[hid].report())
            except Exception:
                self.router.drain(hid)
        rep = {"fleet": merge_reports(host_reps) if host_reps else {},
               "hosts": host_reps,
               "routing": self.router.report(),
               "epoch": self.coordinator.epoch}
        return rep

    def reset_telemetry(self) -> None:
        for hid in self.router.live_hosts():
            self.router._hosts[hid].reset_telemetry()

    def close(self, timeout: float | None = 30.0) -> None:
        """Close every host.  A crash surfacing from a host that was already
        DRAINED is expected (that crash is why it was drained) and is
        swallowed; an error from a live host propagates."""
        live = set(self.router.live_hosts())
        errs = []
        for h in self.hosts:
            try:
                h.close(timeout=timeout)
            except Exception as e:          # noqa: PERF203 — best-effort
                if h.host_id in live:
                    errs.append(e)
        if errs:
            raise errs[0]

    def __enter__(self) -> "AidwCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
