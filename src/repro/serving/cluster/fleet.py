"""AidwCluster — the multi-host serving fleet front end.

Ties the cluster pieces together behind one server-like surface: an
:class:`~repro.serving.cluster.epochs.EpochCoordinator` totally orders
dataset updates, a :class:`~repro.serving.cluster.router.Router` spreads
query traffic over the live hosts, and per-host
:class:`~repro.serving.cluster.host.HostServer` elements (in-process, or
:class:`~repro.serving.cluster.rpc.RemoteHost` proxies for hosts in other
processes) do the serving.

Write path (the epoch-broadcast step of the protocol in
``cluster/epochs.py``): ``update_dataset`` assigns the next epoch and
enqueues the update on EVERY live host while holding the broadcast lock —
pinning the update's position in each host's FIFO relative to all queries
routed before/after — then releases the lock and waits for the fleet to
apply.  Concurrent ``update_dataset`` calls therefore serialize into one
total epoch order but their applications overlap across hosts.  A host
that fails mid-broadcast or mid-wait is drained (its queries resubmit to
survivors); the update succeeds if at least one live host applied it.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.obs import (Registry, Tracer, fleet_epoch_events, new_span_id,
                       tail_attribution)

from .epochs import EpochCoordinator, EpochUpdate
from .host import HostServer
from .router import RoutedRequest, Router
from .telemetry import merge_reports

__all__ = ["AidwCluster", "ShardedAidwCluster", "fleet_partition"]


def _parallel_hosts(items, fn, max_workers: int | None = None) -> list:
    """Run ``fn(item)`` for every host-shaped item on a thread pool and
    return results in order; exceptions re-raise on the caller.  The fleet
    uses this for warmup/flush/fan-out so per-host waits overlap instead of
    summing (the one-deadline-for-the-fleet semantics every caller already
    passes down as absolute remaining time per call)."""
    items = list(items)
    if len(items) <= 1:
        return [fn(it) for it in items]
    with ThreadPoolExecutor(max_workers=max_workers or len(items)) as pool:
        return list(pool.map(fn, items))


def _merge_debugz(host_bundles: dict, unreachable: list, *,
                  epoch=None, routing=None) -> dict:
    """Merge per-host debugz bundles into ONE fleet bundle.

    Registries merge bin-exactly (fleet stage percentiles are computed
    from the union of per-host bins, never averaged); recorder states
    concatenate into :func:`repro.obs.tail_attribution`, so the
    attribution block decomposes the FLEET p99−p50 gap; SLO events are
    the union of per-host breach events plus the fleet-only epoch
    staleness check (no single host can see another's epoch lag)."""
    bundles = dict(host_bundles)
    reg_states = [b["registry"] for b in bundles.values()
                  if b.get("registry")]
    fleet_reg = Registry.merge_states(reg_states) if reg_states \
        else Registry()
    rec_states = [b["recorder"] for b in bundles.values()
                  if b.get("recorder")]
    events = [e for b in bundles.values()
              for e in (b.get("slo") or {}).get("events", [])]
    events += fleet_epoch_events(bundles)
    epochs = {h: b["epoch"] for h, b in bundles.items()
              if b.get("epoch") is not None}
    return {
        "epoch": epoch,
        "hosts": bundles,
        "unreachable": list(unreachable),
        "routing": routing,
        "fleet": {
            "queue_depth": sum(b.get("queue_depth", 0)
                               for b in bundles.values()),
            "epochs": {"min": min(epochs.values()) if epochs else None,
                       "max": max(epochs.values()) if epochs else None,
                       "by_host": epochs},
            "stages": fleet_reg.snapshot(),
        },
        "slo": {"events": events},
        "attribution": tail_attribution(rec_states,
                                        registry_state=fleet_reg.state()),
    }


class AidwCluster:
    """N-host AIDW serving fleet behind one submit/update/flush surface.

    Either hand it ``hosts=`` (pre-built :class:`HostServer`/``RemoteHost``
    elements — the process-backed deployment path) or let it build
    ``n_hosts`` in-process hosts over ``points_xyz``, each with its own
    ``AsyncAidwServer`` (every host serves a full dataset replica;
    ``host_kwargs`` pass through, e.g. ``max_batch=``/``query_domain=``/
    ``mesh=``).  ``policy`` and ``heartbeat_timeout_s`` configure the
    router.
    """

    def __init__(self, points_xyz=None, n_hosts: int = 2, cfg=None, *,
                 hosts=None, policy: str = "round_robin",
                 heartbeat_timeout_s: float = 60.0, clock=time.monotonic,
                 tracer=None, trace_sample_rate: float | None = None,
                 **host_kwargs):
        # fleet-level tracing: ONE sampling decision at the router root
        # (this tracer); hosts get rate-0 tracers so they RECORD propagated
        # trace contexts but never start fleet-invisible roots of their own
        if tracer is None and trace_sample_rate is not None:
            tracer = Tracer(clock=clock, sample_rate=trace_sample_rate,
                            host="router")
        self.tracer = tracer
        if hosts is None:
            if points_xyz is None:
                raise ValueError("need points_xyz to build in-process hosts")
            if tracer is not None:
                host_kwargs.setdefault("trace_sample_rate", 0.0)
            hosts = [HostServer(i, points_xyz, cfg, clock=clock,
                                **host_kwargs)
                     for i in range(int(n_hosts))]
        self.hosts = list(hosts)
        self.clock = clock
        self.coordinator = EpochCoordinator()
        self.router = Router(self.hosts, policy=policy, clock=clock,
                             heartbeat_timeout_s=heartbeat_timeout_s,
                             tracer=tracer)
        self._bcast = threading.Lock()

    # -- query path ----------------------------------------------------------

    def submit(self, queries_xy, *,
               deadline_s: float | None = None) -> RoutedRequest:
        """Route one query batch to a live host (see :class:`Router`)."""
        return self.router.route(queries_xy, deadline_s=deadline_s)

    def result(self, req: RoutedRequest,
               timeout: float | None = None) -> RoutedRequest:
        """Block until ``req`` is terminal (follows it across host drains)."""
        return self.router.wait(req, timeout=timeout)

    # -- write path ----------------------------------------------------------

    def update_dataset(self, points_xyz=None, *, inserts=None, deletes=None,
                       deltas=None, timeout: float | None = None) -> int:
        """Epoch-ordered fleet-wide dataset update; returns the epoch.

        Broadcast-enqueues under the coordinator lock (total epoch order on
        every host's FIFO), waits for application outside it.  Hosts that
        fail are drained — including on TIMEOUT, deliberately: a timed-out
        wait withdraws the host's op, leaving an epoch gap, and a host
        missing an epoch must leave rotation (consistency over
        availability; the server's gap guard enforces the same thing).
        Raises only when NO host applied the update.
        """
        if deltas is not None:
            inserts, deletes = deltas
        # ONE deadline for the whole fleet wait — hosts apply concurrently,
        # so waiting them out sequentially must not multiply the bound by N
        deadline = None if timeout is None else time.monotonic() + timeout
        return self._broadcast_epoch(
            dict(points_xyz=points_xyz, inserts=inserts, deletes=deletes),
            deadline)

    def compact(self, *, timeout: float | None = None) -> int:
        """Fleet-wide COMPACTION epoch: every host folds its LSM hot ring
        into its slab CSR at the same point in the epoch order (so a single
        server replaying ``coordinator.log`` replays compactions where the
        fleet ran them).  Hosts under cluster epochs never self-compact —
        the coordinator owns the schedule; call this when the merged
        ``report()['fleet']['ingest']['ring_occupancy']`` nears the ring
        high-water.  Returns the epoch."""
        deadline = None if timeout is None else time.monotonic() + timeout
        return self._broadcast_epoch(dict(compact=True), deadline)

    def _broadcast_epoch(self, fields: dict, deadline) -> int:
        tid = self.tracer.new_trace() if self.tracer is not None else None
        root = new_span_id() if tid is not None else None
        t0 = self.clock()
        handles = {}
        with self._bcast:
            upd = self.coordinator.assign(**fields, trace_id=tid,
                                          parent_span=root)
            for hid in self.router.live_hosts():
                host = self.router._hosts[hid]
                try:
                    handles[hid] = (host, host.submit_update(upd))
                except Exception:
                    self.router.drain(hid)
        applied = 0
        first_err: BaseException | None = None
        for hid, (host, handle) in handles.items():
            try:
                host.wait_update(
                    handle, timeout=None if deadline is None
                    else max(deadline - time.monotonic(), 0.0))
                applied += 1
            except BaseException as e:
                first_err = first_err or e
                self.router.drain(hid)
        if tid is not None:
            # root span for the fleet update: every host's apply_epoch span
            # parents on it (root id pre-generated, recorded retroactively)
            self.tracer.record("epoch_update", t0, self.clock(),
                               trace_id=tid, span_id=root,
                               args={"epoch": upd.epoch, "applied": applied})
        if not applied:
            raise first_err if first_err is not None else \
                RuntimeError(f"epoch {upd.epoch}: no live host to update")
        return upd.epoch

    # -- fleet lifecycle -----------------------------------------------------

    @property
    def epoch(self) -> int:
        """Newest assigned epoch (hosts may still be applying it)."""
        return self.coordinator.epoch

    def prewarm(self, *, timeout: float | None = None) -> dict:
        """AOT-compile + warm every live host's WHOLE bucket ladder in
        PARALLEL (the fleet-wide cold-start killer): each host's
        ``prewarm`` control-plane op runs on its own thread under ONE
        fleet deadline, so ladders compile concurrently across hosts
        (and, with a shared persistent compilation cache, every host
        after the first deserializes instead of compiling).  A host that
        merely times out stays in rotation still compiling — slowness is
        not death, same rule as :meth:`warmup`; a host whose prewarm
        ERRORS is drained.  Returns ``{host_id: prewarm status dict}``
        for the hosts that finished in time."""
        deadline = None if timeout is None else time.monotonic() + timeout
        results: dict = {}

        def prewarm_one(hid):
            host = self.router._hosts[hid]
            fn = getattr(host, "prewarm", None)
            if fn is None:
                return
            rem = None if deadline is None \
                else max(deadline - time.monotonic(), 0.0)
            try:
                results[hid] = fn(wait=True, timeout=rem)
            except TimeoutError:
                pass
            except Exception:
                self.router.drain(hid)

        _parallel_hosts(self.router.live_hosts(), prewarm_one)
        return results

    def warmup(self, queries_xy, *, batches_per_host: int = 3,
               timeout: float | None = None, prewarm: bool = False) -> None:
        """Prime every host's executables (and execute-time model) in
        PARALLEL: ``batches_per_host`` copies of ``queries_xy`` submitted
        DIRECTLY to each host (bypassing the router, so round-robin can
        never starve a host of its warm batches) and waited on a thread
        per host under ONE fleet deadline.  Cold-start compiles overlap
        across hosts instead of summing — the dominant cost of the 2-host
        CPU bench rows before this existed.  A host that fails its warmup
        is drained, not fatal.  ``prewarm=True`` first runs the fleet
        :meth:`prewarm` op under the same deadline, so the warm batches
        dispatch to already-AOT-compiled ladder executables."""
        deadline = None if timeout is None else time.monotonic() + timeout
        if prewarm:
            self.prewarm(timeout=timeout)

        def warm_one(hid):
            host = self.router._hosts[hid]
            try:
                reqs = [host.submit(queries_xy)
                        for _ in range(batches_per_host)]
                for r in reqs:
                    rem = None if deadline is None \
                        else max(deadline - time.monotonic(), 0.0)
                    host.wait(r, timeout=rem)
            except TimeoutError:
                # still compiling, not dead: an expired fleet deadline
                # must leave a COLD host in rotation, not drain it (the
                # same slowness-is-not-death rule flush applies)
                pass
            except Exception:
                self.router.drain(hid)

        _parallel_hosts(self.router.live_hosts(), warm_one)

    def flush(self, timeout: float | None = None) -> None:
        """Wait for every routed request to reach a terminal state.

        Host flushes run first, IN PARALLEL on a thread per host under one
        fleet deadline (fast path: lets each worker drain its FIFO; serial
        waits would sum N drain times where the fleet only needs the max);
        a host that fails its flush is drained and its requests follow the
        router's resubmission path.
        """
        deadline = None if timeout is None else time.monotonic() + timeout

        def flush_one(hid):
            rem = None if deadline is None \
                else max(deadline - time.monotonic(), 0.0)
            try:
                self.router._hosts[hid].flush(timeout=rem)
            except TimeoutError:
                # backlogged, not dead: flush is read-only, so slowness must
                # NOT drain the host (the router flush below reports the
                # timeout to the caller; the fleet stays intact for a retry)
                pass
            except Exception:
                self.router.drain(hid)

        _parallel_hosts(self.router.live_hosts(), flush_one)
        self.router.flush(timeout=None if deadline is None
                          else max(deadline - time.monotonic(), 0.0))

    def report(self) -> dict:
        """Merged fleet report + per-host reports + routing counters."""
        host_reps = []
        for hid in self.router.live_hosts():
            try:
                host_reps.append(self.router._hosts[hid].report())
            except Exception:
                self.router.drain(hid)
        rep = {"fleet": merge_reports(host_reps) if host_reps else {},
               "hosts": host_reps,
               "routing": self.router.report(),
               "epoch": self.coordinator.epoch}
        return rep

    def reset_telemetry(self) -> None:
        for hid in self.router.live_hosts():
            self.router._hosts[hid].reset_telemetry()

    def collect_spans(self, drain: bool = True) -> list[dict]:
        """Gather span dicts from the router's tracer AND every live host
        into one list (feed to :func:`repro.obs.chrome_trace` for a single
        connected fleet trace; ``drain=True`` empties all buffers).  A host
        whose span pull fails contributes nothing — collection must never
        drain a host over a diagnostics rpc."""
        out: list[dict] = []
        if self.tracer is not None:
            out.extend(self.tracer.drain() if drain else self.tracer.spans())
        for hid in self.router.live_hosts():
            host = self.router._hosts[hid]
            try:
                out.extend(host.spans(drain=drain))
            except Exception:
                pass
        return out

    def debugz(self) -> dict:
        """One merged fleet diagnostics bundle (JSON-serializable).

        Pulls every live host's ``debugz`` bundle — a host whose pull
        fails is listed under ``unreachable`` and contributes nothing
        (diagnostics must never drain a host, same rule as
        :meth:`collect_spans`; the bundle stays useful mid-incident when
        a host is down, which is exactly when it is pulled) — and merges
        them: bin-exact fleet registry, fleet-level tail-latency
        attribution over the union of flight-recorder states, per-host
        SLO events plus the fleet epoch-staleness check, and routing
        counters."""
        bundles, unreachable = {}, []
        for hid in self.router.live_hosts():
            host = self.router._hosts[hid]
            try:
                bundles[str(hid)] = host.debugz()
            except Exception:
                unreachable.append(str(hid))
        return _merge_debugz(bundles, unreachable,
                             epoch=self.coordinator.epoch,
                             routing=self.router.report())

    def close(self, timeout: float | None = 30.0) -> None:
        """Close every host.  A crash surfacing from a host that was already
        DRAINED is expected (that crash is why it was drained) and is
        swallowed; an error from a live host propagates."""
        live = set(self.router.live_hosts())
        errs = []
        for h in self.hosts:
            try:
                h.close(timeout=timeout)
            except Exception as e:          # noqa: PERF203 — best-effort
                if h.host_id in live:
                    errs.append(e)
        if errs:
            raise errs[0]

    def __enter__(self) -> "AidwCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# Fleet data partitioning (first cut): each host serves ONE SHARD
# ---------------------------------------------------------------------------


def _spec_area(spec) -> float:
    return (spec.n_cols * spec.cell_width) * (spec.n_rows * spec.cell_width)


def fleet_partition(points_xyz, n_shards: int, *, query_domain=None,
                    cell_factor: float = 1.0):
    """Row-slab partition of a dataset for the data-partitioned fleet.

    The grid-aware slab decomposition is the partitioning backbone: the
    coordinator plans the GLOBAL even grid (same ``plan_grid`` call a
    full-replica server would make over the same dataset + query domain, so
    Eq. (2)'s study area matches the replica bitwise) and cuts its rows
    into ``n_shards`` slabs (``repro.core.slab.slab_rows``), so shard
    locality matches grid locality — the cross-host analogue of the
    session's ``grid_ring`` layout, and the substrate future
    locality-aware routing keys on.  Returns ``(spec, rps, members)`` with
    ``members[s]`` the sorted dataset indices shard ``s`` owns.

    Deterministic in its inputs: a subprocess worker reconstructing the
    same dataset computes the identical partition
    (``repro.serving.cluster.rpc.main --shard-of``).
    """
    from repro.core import grid as G
    from repro.core.slab import slab_rows

    pts = np.asarray(points_xyz)
    spec = G.plan_grid(
        pts[:, :2],
        None if query_domain is None else np.asarray(query_domain),
        cell_factor=cell_factor)
    rps = slab_rows(spec, n_shards)
    rows = G.cell_ids_host(spec, pts[:, 0], pts[:, 1]) // spec.n_cols
    shard = np.minimum(rows // rps, n_shards - 1)
    members = [np.nonzero(shard == s)[0].astype(np.int64)
               for s in range(n_shards)]
    return spec, rps, members


class ShardedQueryResult:
    """One fleet-merged query batch: values + the Stage-1 stats the merge
    derived them from, plus the epoch every shard served under.
    ``zero_weight_mask`` marks queries whose f32 weight sum underflowed to
    zero (value is the 0.0 sentinel, never NaN)."""

    def __init__(self, values, alpha, r_obs, overflow_mask, epoch,
                 zero_weight_mask=None):
        self.values = values
        self.alpha = alpha
        self.r_obs = r_obs
        self.overflow_mask = overflow_mask
        self.overflow = int(np.sum(overflow_mask))
        self.epoch = epoch
        self.zero_weight_mask = zero_weight_mask


class ShardedAidwCluster:
    """Data-PARTITIONED serving fleet: ``n_hosts`` hosts, each serving one
    row-slab shard of the dataset (never a replica) — for datasets too
    large to replicate per host.  First cut of fleet data partitioning
    (ROADMAP post-PR-4): query batches fan out to ALL shard hosts and merge
    client-side.

    Query path (two phases, k-way merge — the cross-host mirror of the
    grid-ring layout's neighbour-heap merge):

    1. **kNN fan-out** — every host answers Stage 1 over its shard
       (``shard_knn``: top-k squared distances AND the matching neighbour
       VALUES via the paper's grid search on the host's own plan).  The
       coordinator k-way merges the per-shard (d2, z) heaps into the
       global top-k, from which r_obs and the adaptive alpha (Eqs. 3-6)
       follow — using the GLOBAL point count and the fleet spec's study
       area, which match a full-replica server's plan bitwise (same
       ``plan_grid`` inputs).
    2. **partial-sum fan-out** — every host computes Eq. (1) partial sums
       over its shard at the merged alpha (``shard_partial``); the
       coordinator sums across shards and divides once.  With
       ``AidwConfig(stage2='local')`` this whole phase DISAPPEARS: the
       merged (d2, z) heap already holds everything local Eq. (1) needs,
       so the coordinator finishes the query client-side — one fan-out
       per batch instead of two, and no mid-batch epoch-straddle window
       between phases.

    Every shard op is FIFO-serialized with epoch updates on its host's
    worker and stamped with the epoch it executed under; the coordinator
    verifies all 2N stamps agree and retries the batch when an update
    landed between phases, so a merged result always reflects ONE
    consistent epoch.  Values match a full-replica server within f32
    accumulation tolerance (the partial sums add in shard order);
    ``overflow_mask`` combines per-shard certification flags with a
    client-side slab-gap excuse (a flagged shard whose band lies farther
    than the merged kth distance cannot have corrupted the merge).

    Updates: ``update_dataset`` splits each delta by owning shard (deletes
    resolved through the coordinator's member bookkeeping) and broadcasts
    per-shard pieces under one epoch — EVERY host sees every epoch (empty
    pieces included) so the epoch stream stays dense.  Unlike the
    replicated cluster there are no replicas to drain to: a failed shard
    host makes the fleet unusable and errors propagate loudly (re-sharding
    / shard replication is future work, tracked in ROADMAP).
    """

    def __init__(self, points_xyz=None, n_hosts: int = 2, cfg=None, *,
                 hosts=None, query_domain=None, clock=time.monotonic,
                 tracer=None, trace_sample_rate: float | None = None,
                 **host_kwargs):
        from repro.core import AidwConfig

        if points_xyz is None:
            raise ValueError("need the full dataset to partition the fleet "
                             "(hosts= must match fleet_partition of it)")
        pts = np.asarray(points_xyz)
        self.cfg = cfg or AidwConfig()
        self.clock = clock
        if tracer is None and trace_sample_rate is not None:
            tracer = Tracer(clock=clock, sample_rate=trace_sample_rate,
                            host="coordinator")
        self.tracer = tracer
        self._query_domain = None if query_domain is None \
            else np.asarray(query_domain)
        self.spec, self.rps, self.members = fleet_partition(
            pts, int(n_hosts), query_domain=self._query_domain,
            cell_factor=self.cfg.cell_factor)
        empty = [s for s, mem in enumerate(self.members) if mem.size == 0]
        if empty:
            raise ValueError(
                f"shards {empty} own no points — use fewer hosts or a "
                f"denser dataset (empty shards cannot serve)")
        self.m = pts.shape[0]
        self.area = _spec_area(self.spec)
        if hosts is None:
            if tracer is not None:
                host_kwargs.setdefault("trace_sample_rate", 0.0)
            hosts = [HostServer(s, pts[self.members[s]], cfg,
                                query_domain=query_domain, **host_kwargs)
                     for s in range(int(n_hosts))]
        self.hosts = list(hosts)
        if len(self.hosts) != int(n_hosts):
            # zip() downstream would silently truncate: a shard with no
            # host (or a host with no shard) must fail LOUDLY here
            raise ValueError(
                f"hosts= has {len(self.hosts)} elements for an "
                f"{n_hosts}-way partition — it must match fleet_partition")
        self.coordinator = EpochCoordinator()
        self._bcast = threading.Lock()
        # one persistent fan-out pool: query() fans out twice per batch,
        # and spawning a fresh executor per phase is hot-path overhead
        self._pool = ThreadPoolExecutor(max_workers=len(self.hosts))
        # global (point count, study area, grid spec, rows-per-slab) BY
        # EPOCH: alpha AND the overflow excuse must use the state of the
        # epoch a batch's shard ops actually executed under — reading bare
        # self.* would race update_dataset's commit (hosts apply the new
        # epoch before the coordinator thread returns)
        self._alpha_state = {0: (self.m, self.area, self.spec, self.rps)}

    # -- query path (two-phase fan-out + k-way merge) ------------------------

    def query(self, queries_xy, *, timeout: float | None = None,
              max_retries: int = 3) -> ShardedQueryResult:
        """Answer one query batch against the partitioned dataset.

        Validation shares :func:`repro.serving.queue.validate_queries` with
        the server/router admission surfaces — the shard fan-out must never
        accept an array the replicated path would bounce.
        """
        from repro.serving.queue import validate_queries

        q = validate_queries(queries_xy)
        deadline = None if timeout is None else time.monotonic() + timeout

        def rem():
            return None if deadline is None \
                else max(deadline - time.monotonic(), 0.0)

        k = self.cfg.k
        local = self.cfg.stage2 == "local"
        # fleet query trace: one root ``fanout`` span with phase1 (shard
        # kNN rpc), merge (client-side k-way merge + alpha), and phase2
        # (partial-sum rpc) children, recorded retroactively from clock
        # stamps — the tracing adds no work inside the fan-outs
        tid = self.tracer.new_trace() if self.tracer is not None else None
        root = new_span_id() if tid is not None else None
        t_q0 = self.clock()

        def _span(name, t0, **extra):
            if tid is not None:
                self.tracer.record(name, t0, self.clock(), trace_id=tid,
                                   parent_id=root,
                                   args=extra if extra else None)

        def _root(epoch):
            if tid is not None:
                self.tracer.record("fanout", t_q0, self.clock(),
                                   trace_id=tid, span_id=root,
                                   args={"epoch": epoch,
                                         "queries": int(q.shape[0]),
                                         "shards": len(self.hosts)})

        last_epochs: set = set()
        for _ in range(max_retries):
            t_p1 = self.clock()
            p1 = self._fanout(lambda h: h.shard_knn(q, timeout=rem()))
            _span("phase1", t_p1)
            last_epochs = {r[3] for r in p1}
            if len(last_epochs) != 1:
                continue                     # churn mid-fan-out: retry
            epoch = next(iter(last_epochs))
            # co-merge the per-shard (d2, z) heaps: stable argsort keeps
            # the selected DISTANCES identical to a plain sorted merge
            t_m = self.clock()
            cat_d2 = np.concatenate([r[0] for r in p1], axis=1)
            cat_z = np.concatenate([r[1] for r in p1], axis=1)
            sel = np.argsort(cat_d2, axis=1, kind="stable")[:, :k]
            merged = np.take_along_axis(cat_d2, sel, axis=1)
            merged_z = np.take_along_axis(cat_z, sel, axis=1)
            r_obs = np.sqrt(np.maximum(merged, 0.0)).mean(axis=1)
            alpha = self._alpha(r_obs, epoch)
            overflow_mask = self._merged_overflow(
                q, merged, [r[2] for r in p1], epoch)
            _span("merge", t_m)
            if local:
                # local Stage 2: the merged heap IS the answer — no second
                # fan-out, so no epoch-straddle window either
                from repro.core import aidw as A

                swz, sw = A.topk_weighted_partial_sums(
                    merged.astype(np.float32), merged_z.astype(np.float32),
                    alpha.astype(np.float32))
                vals, zero = A.guarded_values(swz, sw)
                _root(epoch)
                return ShardedQueryResult(
                    values=np.asarray(vals), alpha=alpha, r_obs=r_obs,
                    overflow_mask=overflow_mask, epoch=epoch,
                    zero_weight_mask=np.asarray(zero))
            t_p2 = self.clock()
            p2 = self._fanout(
                lambda h: h.shard_partial(q, alpha, timeout=rem()))
            _span("phase2", t_p2)
            last_epochs = {epoch} | {r[2] for r in p2}
            if len(last_epochs) == 1:
                swz = np.sum([r[0] for r in p2], axis=0)
                sw = np.sum([r[1] for r in p2], axis=0)
                zero = sw <= 0.0
                vals = np.where(zero, 0.0, swz / np.where(zero, 1.0, sw))
                _root(epoch)
                return ShardedQueryResult(
                    values=vals, alpha=alpha, r_obs=r_obs,
                    overflow_mask=overflow_mask, epoch=epoch,
                    zero_weight_mask=zero)
            # an update landed between phases/hosts: the merge would mix
            # epochs — retry the whole batch (updates are rare vs queries)
        raise RuntimeError(
            f"query kept straddling dataset updates after {max_retries} "
            f"attempts (saw epochs {sorted(last_epochs)})")

    def _fanout(self, fn) -> list:
        return list(self._pool.map(fn, self.hosts))

    def _epoch_state(self, epoch: int):
        with self._bcast:
            return self._alpha_state.get(
                epoch, (self.m, self.area, self.spec, self.rps))

    def _alpha(self, r_obs: np.ndarray, epoch: int) -> np.ndarray:
        from repro.core import adaptive_alpha

        m, area, _, _ = self._epoch_state(epoch)
        return np.asarray(adaptive_alpha(
            r_obs.astype(np.float32), np.float32(m),
            np.float32(area), alphas=self.cfg.alphas,
            r_min=self.cfg.r_min, r_max=self.cfg.r_max))

    def _merged_overflow(self, q, merged_d2, shard_masks,
                         epoch: int) -> np.ndarray:
        """Fleet certification: a shard's un-certified Stage-1 only taints
        a query if points it may have missed could beat the merged kth
        distance — and every point it owns lies in its row band, so a band
        farther than ``d_k`` excuses the flag (the client-side mirror of
        the grid-ring layout's per-slab overflow excuse).  Grid geometry
        pinned to the batch's EPOCH, like the alpha state — a full refresh
        committing mid-query must not re-interpret old-epoch distances
        against the new grid."""
        from repro.core import grid as G

        _, _, spec, rps = self._epoch_state(epoch)
        rows = G.cell_ids_host(spec, q[:, 0], q[:, 1]) // spec.n_cols
        d_k = np.sqrt(np.maximum(merged_d2[:, -1], 0.0))
        flag = np.zeros(q.shape[0], bool)
        for s, mask in enumerate(shard_masks):
            lo = s * rps
            hi = spec.n_rows if s == len(shard_masks) - 1 \
                else (s + 1) * rps
            gap = np.maximum(0, np.maximum(lo - rows, rows - (hi - 1)))
            flag |= np.asarray(mask, bool) \
                & (d_k > (gap - 1.0) * spec.cell_width)
        return flag

    # -- write path (epoch-ordered, split by owning shard) -------------------

    def _split_update(self, points_xyz, inserts, deletes):
        """Per-host update payloads (epoch filled in at broadcast) + the
        NEW partition state to commit.  Runs — and VALIDATES — before any
        epoch is assigned: a rejected update must not consume an epoch, or
        the gap would wedge every host's EpochApplier forever.

        A FULL refresh re-plans the fleet grid over the new dataset (same
        ``fleet_partition`` call as construction), so Eq. (2)'s study area
        and the shard routing track the data exactly like a full-replica
        server's re-plan would.  A DELTA keeps the spec frozen (the same
        plan-freeze contract as ``plan_delta``) and therefore REJECTS
        inserts outside the planned bounding box — the caller re-syncs
        with a full refresh, matching the replica's fallback behaviour.
        """
        from repro.core import grid as G
        from repro.core.slab import member_delta

        spec, rps, p = self.spec, self.rps, len(self.hosts)
        if points_xyz is not None:
            pts = np.asarray(points_xyz)
            spec2, rps2, members = fleet_partition(
                pts, p, query_domain=self._query_domain,
                cell_factor=self.cfg.cell_factor)
            empty = [s for s, mem in enumerate(members) if mem.size == 0]
            if empty:
                raise ValueError(f"full update leaves shards {empty} empty")
            ups = [{"points_xyz": pts[members[s]]} for s in range(p)]
            commit = {"members": members, "m": pts.shape[0], "spec": spec2,
                      "rps": rps2, "area": _spec_area(spec2)}
            return ups, commit
        dels = np.unique(np.asarray(deletes, dtype=np.int64)) \
            if deletes is not None and np.size(deletes) else None
        if dels is not None and (dels[0] < 0 or dels[-1] >= self.m):
            raise IndexError(f"delete index out of range [0, {self.m})")
        ins = np.asarray(inserts) if inserts is not None \
            and np.size(inserts) else None
        ins_shard = None
        if ins is not None:
            if (ins[:, 0] < spec.min_x).any() or (ins[:, 1] < spec.min_y).any() \
                    or (ins[:, 0] > spec.min_x
                        + spec.n_cols * spec.cell_width).any() \
                    or (ins[:, 1] > spec.min_y
                        + spec.n_rows * spec.cell_width).any():
                raise ValueError(
                    "delta insert outside the fleet's planned grid — "
                    "re-sync with a full dataset update (the fleet spec "
                    "is frozen across deltas, like plan_delta's bbox "
                    "fallback)")
            rows = G.cell_ids_host(spec, ins[:, 0], ins[:, 1]) // spec.n_cols
            ins_shard = np.minimum(rows // rps, p - 1)
        m_kept = self.m - (0 if dels is None else dels.size)
        ups, members = [], []
        for s in range(p):
            sel = None if ins_shard is None else ins_shard == s
            has_ins = sel is not None and bool(sel.any())
            dels_local, mem = member_delta(
                self.members[s], dels, m_kept,
                np.nonzero(sel)[0] if has_ins else None)
            # EVERY host gets an update for EVERY epoch — empty pieces
            # keep the per-host epoch streams dense (the server's
            # monotonicity guard requires it)
            ups.append({
                "inserts": ins[sel] if has_ins
                else np.zeros((0, 3), np.float32),
                "deletes": dels_local if dels_local is not None
                and dels_local.size else None})
            members.append(mem)
        commit = {"members": members,
                  "m": m_kept + (0 if ins is None else ins.shape[0]),
                  "spec": spec, "rps": rps, "area": self.area}
        return ups, commit

    def update_dataset(self, points_xyz=None, *, inserts=None, deletes=None,
                       deltas=None, timeout: float | None = None) -> int:
        """Epoch-ordered fleet update, split by owning shard; returns the
        epoch.  Broadcast-enqueues under the coordinator lock (same FIFO
        pinning as the replicated cluster), waits for all hosts in
        parallel on one deadline.  Any per-host failure propagates — with
        partitioned data there is no surviving replica to drain to."""
        if deltas is not None:
            inserts, deletes = deltas
        deadline = None if timeout is None else time.monotonic() + timeout
        tid = self.tracer.new_trace() if self.tracer is not None else None
        root = new_span_id() if tid is not None else None
        t0 = self.clock()
        with self._bcast:
            # split + validate FIRST: only a broadcastable update may
            # consume an epoch (a gap would wedge every host's applier)
            ups, commit = self._split_update(points_xyz, inserts, deletes)
            upd = self.coordinator.assign(points_xyz=points_xyz,
                                          inserts=inserts, deletes=deletes,
                                          trace_id=tid, parent_span=root)
            handles = [host.submit_update(
                EpochUpdate(epoch=upd.epoch, trace_id=tid,
                            parent_span=root, **u))
                for host, u in zip(self.hosts, ups)]
            # commit the partition state under the lock: the NEXT update's
            # delete indices reference this epoch's dataset order, and
            # queries resolve their alpha (m, area) via _alpha_state
            self.members = commit["members"]
            self.m = commit["m"]
            self.spec = commit["spec"]
            self.rps = commit["rps"]
            self.area = commit["area"]
            self._alpha_state[upd.epoch] = (self.m, self.area, self.spec,
                                            self.rps)
            for old in [e for e in self._alpha_state
                        if e < upd.epoch - 8]:   # bounded history
                del self._alpha_state[old]
        _parallel_hosts(
            zip(self.hosts, handles),
            lambda hw: hw[0].wait_update(
                hw[1], timeout=None if deadline is None
                else max(deadline - time.monotonic(), 0.0)))
        if tid is not None:
            self.tracer.record("epoch_update", t0, self.clock(),
                               trace_id=tid, span_id=root,
                               args={"epoch": upd.epoch})
        return upd.epoch

    def compact(self, *, timeout: float | None = None) -> int:
        """Fleet-wide COMPACTION epoch across all shards: each host folds
        its own shard's hot ring into its slab CSR at the same point in the
        epoch order.  Partition state (members/m/spec) is unchanged —
        compaction moves points between tiers, never between shards.
        Returns the epoch."""
        deadline = None if timeout is None else time.monotonic() + timeout
        tid = self.tracer.new_trace() if self.tracer is not None else None
        root = new_span_id() if tid is not None else None
        t0 = self.clock()
        with self._bcast:
            upd = self.coordinator.assign(compact=True, trace_id=tid,
                                          parent_span=root)
            handles = [host.submit_update(
                EpochUpdate(epoch=upd.epoch, compact=True, trace_id=tid,
                            parent_span=root))
                for host in self.hosts]
            self._alpha_state[upd.epoch] = (self.m, self.area, self.spec,
                                            self.rps)
        _parallel_hosts(
            zip(self.hosts, handles),
            lambda hw: hw[0].wait_update(
                hw[1], timeout=None if deadline is None
                else max(deadline - time.monotonic(), 0.0)))
        if tid is not None:
            self.tracer.record("epoch_update", t0, self.clock(),
                               trace_id=tid, span_id=root,
                               args={"epoch": upd.epoch, "compact": True})
        return upd.epoch

    # -- fleet lifecycle -----------------------------------------------------

    @property
    def epoch(self) -> int:
        return self.coordinator.epoch

    def prewarm(self, *, timeout: float | None = None) -> dict:
        """AOT-compile + warm every shard host's bucket ladder in
        PARALLEL under one fleet deadline (see
        :meth:`AidwCluster.prewarm`).  Unlike the replicated fleet there
        are no replicas to drain to, so a shard whose prewarm ERRORS
        propagates loudly; a shard that merely runs past the deadline is
        skipped (still compiling, will finish lazily).  Returns
        ``{shard_index: prewarm status dict}``."""
        deadline = None if timeout is None else time.monotonic() + timeout

        def prewarm_one(item):
            s, host = item
            fn = getattr(host, "prewarm", None)
            if fn is None:
                return None
            rem = None if deadline is None \
                else max(deadline - time.monotonic(), 0.0)
            try:
                return s, fn(wait=True, timeout=rem)
            except TimeoutError:
                return None

        out = _parallel_hosts(enumerate(self.hosts), prewarm_one)
        return dict(r for r in out if r is not None)

    def flush(self, timeout: float | None = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        _parallel_hosts(
            self.hosts,
            lambda h: h.flush(timeout=None if deadline is None
                              else max(deadline - time.monotonic(), 0.0)))

    def report(self) -> dict:
        host_reps = _parallel_hosts(self.hosts, lambda h: h.report())
        return {"fleet": merge_reports(host_reps) if host_reps else {},
                "hosts": host_reps, "epoch": self.coordinator.epoch,
                "n_points": self.m,
                "shard_sizes": [int(mem.size) for mem in self.members]}

    def collect_spans(self, drain: bool = True) -> list[dict]:
        """Coordinator + per-shard span dicts as one list (see
        :meth:`AidwCluster.collect_spans`)."""
        out: list[dict] = []
        if self.tracer is not None:
            out.extend(self.tracer.drain() if drain else self.tracer.spans())
        for host in self.hosts:
            try:
                out.extend(host.spans(drain=drain))
            except Exception:
                pass
        return out

    def debugz(self) -> dict:
        """Merged shard-fleet diagnostics bundle (see
        :meth:`AidwCluster.debugz`; shards have no router, so ``routing``
        is ``None`` and unreachable shards are listed by index)."""
        bundles, unreachable = {}, []
        for host in self.hosts:
            hid = str(getattr(host, "host_id", len(bundles)))
            try:
                bundles[hid] = host.debugz()
            except Exception:
                unreachable.append(hid)
        return _merge_debugz(bundles, unreachable,
                             epoch=self.coordinator.epoch)

    def close(self, timeout: float | None = 30.0) -> None:
        errs = []
        for h in self.hosts:
            try:
                h.close(timeout=timeout)
            except Exception as e:          # noqa: PERF203 — best-effort
                errs.append(e)
        self._pool.shutdown(wait=True)
        if errs:
            raise errs[0]

    def __enter__(self) -> "ShardedAidwCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
