"""Query routing across the serving fleet, with heartbeat-driven draining.

The router is the fleet's single query entry point: it picks a live host
per request (``round_robin`` or queue-depth-aware ``least_loaded``),
submits there, and hands back a :class:`RoutedRequest` the client waits on.
Host health reuses :class:`repro.runtime.fault_tolerance.HeartbeatMonitor`
— the same policy object the training fleet uses for node death — plus an
in-band signal: any transport/worker error surfacing from a host while
submitting or waiting drains that host immediately (faster than waiting
out the heartbeat timeout).

Draining contract (exactly-once, client-visible): when a host is drained,
every routed request whose CURRENT attempt sits on that host and is not
terminal is resubmitted to a surviving host.  A request resolves exactly
once — ``RoutedRequest`` latches the first terminal attempt and later
attempts' results are never surfaced (execution is at-least-once across
the fleet, which is safe because queries are read-only and every host
serves the same epoch-ordered dataset).  Deadlines carry across
resubmission as absolute times on the router's clock: a request whose
deadline expired while its host died is shed, never served late.
"""

from __future__ import annotations

import itertools
import threading
import time

import numpy as np

from repro.obs import new_span_id
from repro.runtime.fault_tolerance import HeartbeatMonitor
from repro.serving.queue import AdmissionQueueFull, validate_queries

__all__ = ["Router", "RoutedRequest", "NoLiveHosts"]


class NoLiveHosts(RuntimeError):
    """Every fleet host is drained — nothing can serve."""


class RoutedRequest:
    """Client-facing handle for one cluster query.

    ``status``/``values``/``overflow``/``epoch`` populate when the request
    reaches a terminal state (``done``, ``shed``, or — only when the whole
    fleet drained under it — ``failed``); ``host_id`` names the host whose
    attempt actually resolved.  All mutation happens under the router's
    lock.
    """

    def __init__(self, uid: int, queries_xy, deadline: float | None):
        self.uid = uid
        self.queries_xy = queries_xy
        self.deadline = deadline          # absolute on the router clock
        self.status = "routed"
        self.done = False
        self.values = None
        self.overflow = 0
        self.epoch: int | None = None
        self.host_id = None
        self.attempts: list = []          # [(host_id, inner_request), ...]
        self.trace_id: str | None = None  # obs trace context (router root)
        self.root_span: str | None = None

    def _current(self):
        return self.attempts[-1]

    def _resolve(self, host_id, inner) -> None:
        if self.done:                     # first terminal attempt wins
            return
        self.status = inner.status
        self.values = inner.values
        self.overflow = inner.overflow
        self.epoch = getattr(inner, "epoch", None)
        self.host_id = host_id
        self.done = True


class Router:
    """Pick-a-host policy + routed-request registry + drain logic.

    ``hosts`` implement the :class:`repro.serving.cluster.host.HostServer`
    surface (local or RPC-remote).  ``policy``: ``"round_robin"`` cycles
    live hosts; ``"least_loaded"`` routes to the smallest shard-local
    admission-queue depth (ties broken round-robin).  ``monitor`` defaults
    to a fresh :class:`HeartbeatMonitor` over the host ids; call
    :meth:`beat` when a host shows signs of life and :meth:`check` to
    drain anything past the heartbeat timeout.
    """

    POLICIES = ("round_robin", "least_loaded")

    def __init__(self, hosts, *, policy: str = "round_robin", monitor=None,
                 heartbeat_timeout_s: float = 60.0,
                 admission_timeout_s: float = 30.0, clock=time.monotonic,
                 tracer=None):
        if policy not in self.POLICIES:
            raise ValueError(f"policy must be one of {self.POLICIES}, "
                             f"got {policy!r}")
        if not hosts:
            raise ValueError("router needs at least one host")
        self.policy = policy
        self.clock = clock
        # optional obs tracer (must share ``clock``): route() samples at
        # the fleet root and the per-host trace context propagates through
        # host.submit — including drain resubmissions, which record child
        # ``resubmit`` spans under the ORIGINAL trace
        self.tracer = tracer
        # bounds host.submit under backpressure: the router lock is held
        # across submission, so an unbounded block would stall the fleet
        self.admission_timeout_s = admission_timeout_s
        self._hosts = {h.host_id: h for h in hosts}
        self._live = [h.host_id for h in hosts]
        self.monitor = monitor or HeartbeatMonitor(
            list(self._hosts), timeout_s=heartbeat_timeout_s, clock=clock)
        self._rr = 0
        self._uid = itertools.count()
        self._lock = threading.RLock()
        self._routed: dict[int, RoutedRequest] = {}
        # shed_expired: requests whose deadline had already passed when the
        # router went to (re)submit them — overload backpressure at route
        # time, or budget burned while their original host was draining
        self.counters = {"routed": 0, "resubmitted": 0, "drained_hosts": 0,
                         "shed_expired": 0, "failed": 0}

    # -- host selection ------------------------------------------------------

    def live_hosts(self) -> list:
        with self._lock:
            return list(self._live)

    def _probe_depths(self) -> dict:
        """Queue-depth snapshot for least_loaded selection, taken WITHOUT
        the router lock (a remote depth probe is an RPC; blocking the
        fleet-wide lock on it would stall every route/wait).  A host whose
        probe raises is drained — dead hosts must not wedge selection."""
        with self._lock:
            live = list(self._live)
        depths = {}
        for h in live:
            try:
                depths[h] = self._hosts[h].queue_depth()
            except Exception:
                self.drain(h)
        return depths

    def _pick_locked(self, depths: dict | None = None):
        if not self._live:
            raise NoLiveHosts("all fleet hosts drained")
        order = self._live[self._rr:] + self._live[:self._rr]
        if self.policy == "least_loaded" and depths \
                and any(h in depths for h in order):
            # stale entries for since-drained hosts were filtered by using
            # the CURRENT live order; unknown depths sort last (rr fallback)
            hid = min(order, key=lambda h: depths.get(h, float("inf")))
        else:
            hid = order[0]
        self._rr = (self._rr + 1) % max(len(self._live), 1)
        return hid

    # -- query path ----------------------------------------------------------

    def route(self, queries_xy, *, deadline_s: float | None = None
              ) -> RoutedRequest:
        """Submit one query batch to a live host; returns the routed handle.

        A host that fails at submit time (dead worker, broken transport) is
        drained in-band and the request retries on the survivors.
        """
        # validate HERE, not by bouncing off a host: a malformed array would
        # raise host-side, be mistaken for host death, and drain the fleet
        q = validate_queries(queries_xy)
        now = self.clock()
        rr = RoutedRequest(
            next(self._uid), q,
            None if deadline_s is None else now + deadline_s)
        if self.tracer is not None:
            rr.trace_id = self.tracer.new_trace()  # fleet-root sampling
            if rr.trace_id is not None:
                # pre-generate the root span id: hosts parent their serving
                # spans on it BEFORE the route span itself is recorded
                rr.root_span = new_span_id()
        with self._lock:
            self._routed[rr.uid] = rr
            self.counters["routed"] += 1
        try:
            self._submit(rr)
        except BaseException:
            # never-submitted request must not stay registered: a later
            # flush()/wait() would trip over its empty attempts list
            with self._lock:
                del self._routed[rr.uid]
                self.counters["routed"] -= 1
            raise
        return rr

    def _submit(self, rr: RoutedRequest) -> None:
        """Place ``rr`` on a live host.

        Lock policy: the router lock is held only around host SELECTION and
        attempt RECORDING, never across the host submit itself — one hung
        host must cost its own admission timeout, not stall every other
        route()/wait() contending for the lock.  (Drain-time resubmission
        enters with the reentrant lock already held; that rare path accepts
        the serialization.)
        """
        full: set = set()                  # backpressured (NOT dead) hosts
        resubmit = bool(rr.attempts)       # drain-time placement, not fresh
        t_place = self.clock()
        while True:
            depths = self._probe_depths() \
                if self.policy == "least_loaded" else None
            with self._lock:
                try:
                    hid = self._pick_locked(depths)
                except NoLiveHosts:
                    if rr.attempts:
                        # resubmission path (drain cascade emptied the
                        # fleet): terminate instead of crashing the drainer
                        rr.status = "failed"
                        rr.done = True
                        self.counters["failed"] += 1
                        return
                    raise                  # fresh route(): surface to caller
                if hid in full:
                    if full >= set(self._live):
                        # the WHOLE fleet is backpressured: overload, not
                        # failure — surface it like the server would for a
                        # fresh route; a resubmission has no caller to push
                        # back on, so it terminates loudly instead
                        if rr.attempts:
                            rr.status = "failed"
                            rr.done = True
                            self.counters["failed"] += 1
                            return
                        raise AdmissionQueueFull(
                            "every live host's admission queue is full")
                    continue               # round-robin past the full host
                remaining = None
                if rr.deadline is not None:
                    remaining = rr.deadline - self.clock()
                    if remaining <= 0:     # expired while hostless: shed
                        rr.status = "shed"
                        rr.done = True
                        self.counters["shed_expired"] += 1
                        return
                host = self._hosts[hid]
            # trace kwargs ride only on sampled requests, so hosts without
            # the tracing surface (stubs, older impls) keep working on the
            # untraced path
            tkw = {} if rr.trace_id is None else \
                {"trace_id": rr.trace_id, "parent_span": rr.root_span}
            try:
                inner = host.submit(rr.queries_xy, deadline_s=remaining,
                                    timeout=self.admission_timeout_s, **tkw)
            except AdmissionQueueFull:
                full.add(hid)              # backpressure != death: no drain
                self.monitor.beat(hid)
                continue
            except Exception:
                self.drain(hid)
                continue
            self.monitor.beat(hid)         # responded: in-band liveness
            with self._lock:
                if hid not in self._live and not inner.done:
                    # the host was drained while we were submitting to it
                    # (its drain scan ran before this attempt existed, so
                    # nothing will ever resubmit us): place it again.  The
                    # duplicate execution is safe — queries are read-only
                    # and only the first terminal attempt resolves.
                    continue
                rr.attempts.append((hid, inner))
                if inner.done:             # shed on arrival at the host
                    rr._resolve(hid, inner)
            if self.tracer is not None and rr.trace_id is not None:
                if resubmit:
                    # a drain-time resubmission is a CHILD of the original
                    # route span on the SAME trace — the kill-mid-batch
                    # story stays one connected trace, never a new one
                    self.tracer.record(
                        "resubmit", t_place, self.clock(),
                        trace_id=rr.trace_id, parent_id=rr.root_span,
                        args={"host": str(hid), "attempt": len(rr.attempts)})
                else:
                    self.tracer.record(
                        "route", t_place, self.clock(),
                        trace_id=rr.trace_id, span_id=rr.root_span,
                        args={"host": str(hid)})
            return

    def wait(self, rr: RoutedRequest,
             timeout: float | None = None) -> RoutedRequest:
        """Block until ``rr`` is terminal, following it across drains.

        Waits on the current attempt in short slices; a host error drains
        that host (resubmitting ``rr`` among its victims) and the loop
        follows the fresh attempt.  Raises TimeoutError past ``timeout``.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._lock:
                if rr.done:
                    return rr
                if not rr.attempts:        # route() unregisters these, but
                    raise RuntimeError(    # guard against foreign handles
                        f"request {rr.uid} was never submitted to a host")
                hid, inner = rr._current()
                host = self._hosts[hid]
            slice_s = 0.2
            if deadline is not None:
                slice_s = min(slice_s, max(deadline - time.monotonic(), 0.0))
            try:
                host.wait(inner, timeout=slice_s)
                self.monitor.beat(hid)
                with self._lock:
                    if inner.done:
                        rr._resolve(hid, inner)
            except TimeoutError:
                # a timed-out wait is still a RESPONSE (the host answered
                # "not done yet") — only transport/worker errors mean death
                self.monitor.beat(hid)
            except Exception:
                # dead worker / broken transport: drain in-band (this
                # resubmits rr, so the next loop waits on the new attempt)
                self.drain(hid)
            self.check()
            with self._lock:
                if rr.done:
                    return rr
            if deadline is not None and time.monotonic() >= deadline:
                raise TimeoutError(
                    f"routed request {rr.uid} not terminal after {timeout}s")

    # -- health / draining ---------------------------------------------------

    def beat(self, host_id) -> None:
        self.monitor.beat(host_id)

    def check(self) -> list:
        """Probe every host whose heartbeat went stale; drain the ones that
        FAIL the probe and return their ids.

        A stale heartbeat alone is not death — an idle fleet sees no
        in-band traffic, and draining untouched-but-healthy hosts would
        silently collapse it (there is no re-admission path yet).  The
        probe (``host.probe()``, falling back to ``queue_depth()``) asks
        the host directly; answering refreshes its heartbeat.
        """
        with self._lock:
            stale = [h for h in self.monitor.dead_hosts() if h in self._live]
        drained = []
        for h in stale:
            host = self._hosts[h]
            probe = getattr(host, "probe", host.queue_depth)
            try:
                probe()
                self.monitor.beat(h)       # idle but answering: alive
            except Exception:
                with self._lock:
                    if h in self._live:
                        self._drain_locked(h)
                        drained.append(h)
        return drained

    def drain(self, host_id) -> int:
        """Remove ``host_id`` from rotation and resubmit its non-terminal
        routed requests to survivors; returns how many were resubmitted."""
        with self._lock:
            return self._drain_locked(host_id)

    def _drain_locked(self, host_id) -> int:
        if host_id not in self._live:
            return 0
        self._live.remove(host_id)
        self.monitor.remove(host_id)       # drained: stop tracking liveness
        self.counters["drained_hosts"] += 1
        victims = [rr for rr in self._routed.values()
                   if not rr.done and rr.attempts
                   and rr._current()[0] == host_id]
        n = 0
        for rr in victims:
            # latch a terminal inner first: a request that completed just
            # before the drain keeps its result (no duplicated resolution)
            hid, inner = rr._current()
            if getattr(inner, "done", False):
                rr._resolve(hid, inner)
                continue
            self._submit(rr)               # may shed if deadline expired
            n += 1
        self.counters["resubmitted"] += n
        return n

    # -- lifecycle -----------------------------------------------------------

    def flush(self, timeout: float | None = None) -> None:
        """Wait until every routed request is terminal, reaping as it goes."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            pending = [rr for rr in self._routed.values() if not rr.done]
        for rr in pending:
            rem = None if deadline is None \
                else max(deadline - time.monotonic(), 0.0)
            self.wait(rr, timeout=rem)
        with self._lock:
            self._routed = {u: r for u, r in self._routed.items()
                            if not r.done}

    def report(self) -> dict:
        with self._lock:
            return {
                **self.counters,
                "policy": self.policy,
                "live_hosts": list(self._live),
                "in_flight": sum(not r.done for r in self._routed.values()),
            }
