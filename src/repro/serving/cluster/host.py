"""Per-host serving element: one AsyncAidwServer behind the epoch protocol.

A :class:`HostServer` is what the cluster routes to — it owns the host's
shard-local admission queue (the wrapped
:class:`repro.serving.server.AsyncAidwServer`'s own bounded queue, so
backpressure and deadline shedding stay host-local) and guards the write
path with an :class:`repro.serving.cluster.epochs.EpochApplier`, so dataset
updates enter the local FIFO strictly in fleet epoch order no matter how
the transport delivered them.

The same surface is implemented by :class:`repro.serving.cluster.rpc
.RemoteHost` for hosts living in other processes, which is what lets the
router and fleet front end treat local and remote hosts identically:

    submit(queries, deadline_s) -> request      wait(request, timeout)
    submit_update(EpochUpdate)  -> UpdateHandle wait_update(handle, timeout)
    queue_depth() / epoch / flush / report / reset_telemetry / close
"""

from __future__ import annotations

import time

from ..server import AsyncAidwServer
from .epochs import EpochApplier, EpochUpdate, UpdateHandle

__all__ = ["HostServer"]


class HostServer:
    """One fleet host: ``AsyncAidwServer`` + ordered epoch application.

    ``host_id`` is the fleet identity (``ClusterContext.host_id`` for
    process-backed hosts, a dense index for in-process fleets);
    ``server_kwargs`` pass through to :class:`AsyncAidwServer` (``mesh=``
    serves this host's local device mesh).
    """

    def __init__(self, host_id, points_xyz, cfg=None, *,
                 update_admission_timeout_s: float = 30.0, **server_kwargs):
        self.host_id = host_id
        # bounds the BROADCAST-side enqueue of an epoch update: the fleet
        # coordinator holds its broadcast lock across submit_update, so a
        # full admission queue must raise at a bound (the fleet then drains
        # this host — consistency over availability), never block forever
        self.update_admission_timeout_s = update_admission_timeout_s
        # the fleet identity doubles as the tracer's host lane (Chrome
        # ``pid``), so per-host spans land in per-host lanes of one trace
        server_kwargs.setdefault("host_id", host_id)
        self.server = AsyncAidwServer(points_xyz, cfg, **server_kwargs)
        self.applier = EpochApplier(self._enqueue_update,
                                    applied_epoch=self.server.epoch)

    # -- query path ----------------------------------------------------------

    def submit(self, queries_xy, *, deadline_s: float | None = None,
               uid: int | None = None, timeout: float | None = None,
               trace_id: str | None = None, parent_span: str | None = None):
        """``timeout`` bounds admission under backpressure — a full queue
        raises :class:`~repro.serving.queue.AdmissionQueueFull` at the
        bound instead of blocking forever (the router holds its fleet lock
        across this call, so unbounded blocking here would stall routing
        fleet-wide).  ``trace_id``/``parent_span`` propagate the router's
        trace context into the host's serving spans."""
        return self.server.submit(queries_xy, deadline_s=deadline_s, uid=uid,
                                  timeout=timeout, trace_id=trace_id,
                                  parent_span=parent_span)

    def wait(self, req, timeout: float | None = None):
        return self.server.result(req, timeout=timeout)

    # -- write path (epoch-ordered) ------------------------------------------

    def _enqueue_update(self, upd: EpochUpdate):
        if upd.compact:
            return self.server.submit_compaction(
                epoch=upd.epoch, timeout=self.update_admission_timeout_s,
                trace_id=upd.trace_id, parent_span=upd.parent_span)
        return self.server.submit_update(
            upd.points_xyz, inserts=upd.inserts, deletes=upd.deletes,
            epoch=upd.epoch, timeout=self.update_admission_timeout_s,
            trace_id=upd.trace_id, parent_span=upd.parent_span)

    def submit_update(self, upd: EpochUpdate) -> UpdateHandle:
        """Offer one epoch-tagged update; in-order epochs enqueue into the
        local FIFO before this returns (the broadcast-order guarantee the
        coordinator relies on), early ones buffer until the gap fills."""
        return self.applier.offer(upd)

    def wait_update(self, handle: UpdateHandle,
                    timeout: float | None = None) -> None:
        """Block until the offered update is applied on this host.

        ``timeout`` bounds the WHOLE wait — bound (enqueued once the epoch
        gap fills) plus applied — on one deadline, not once per stage."""
        deadline = None if timeout is None else time.monotonic() + timeout
        if not handle.wait_bound(timeout):
            raise TimeoutError(
                f"epoch {handle.epoch} never enqueued on host "
                f"{self.host_id} (gap in the epoch stream?)")
        if handle.error is not None:
            raise handle.error
        if handle.duplicate:
            return
        self.server.wait_update(
            handle.op, timeout=None if deadline is None
            else max(deadline - time.monotonic(), 0.0))

    # -- shard path (fleet data partitioning) --------------------------------

    def shard_knn(self, queries_xy, *, timeout: float | None = None):
        """This shard's Stage-1 top-k distances + neighbour values
        (+ certification mask + serving epoch) — FIFO-serialized with
        epoch updates on the worker
        (see :meth:`repro.serving.server.AsyncAidwServer.shard_knn`)."""
        return self.server.shard_knn(queries_xy, timeout=timeout)

    def shard_partial(self, queries_xy, alpha, *,
                      timeout: float | None = None):
        """This shard's Stage-2 partial sums at the fleet-merged alpha."""
        return self.server.shard_partial(queries_xy, alpha, timeout=timeout)

    # -- routing / fleet surface ---------------------------------------------

    def prewarm(self, wait: bool = True,
                timeout: float | None = None) -> dict:
        """AOT-compile + warm this host's whole bucket ladder (the fleet
        control-plane prewarm op): a joining or restarted host calls this
        BEFORE entering rotation, so its first routed batch dispatches to
        an already-compiled executable.  Returns the server's prewarm
        status dict (prewarmed flag, live AOT bucket count,
        persistent-compilation-cache stats)."""
        return self.server.prewarm(wait=wait, timeout=timeout)

    @property
    def epoch(self) -> int:
        return self.server.epoch

    def queue_depth(self) -> int:
        """Shard-local admission-queue depth (the least-loaded routing
        signal; cheap — one lock acquisition, no device sync)."""
        return len(self.server.queue)

    def probe(self) -> int:
        """Active liveness probe: raises if this host cannot serve (dead
        worker), else returns the queue depth.  The router calls this for
        hosts whose heartbeat went stale — an IDLE host passes the probe
        and stays in rotation; only a host that fails it is drained."""
        if not self.server.alive:
            raise RuntimeError(f"host {self.host_id} worker is dead")
        return self.queue_depth()

    def flush(self, timeout: float | None = None) -> None:
        self.server.flush(timeout=timeout)

    def report(self) -> dict:
        rep = self.server.report()
        rep["host_id"] = self.host_id
        return rep

    def reset_telemetry(self) -> None:
        """Zero this host's telemetry + admission counters (load harnesses
        call it fleet-wide after warmup)."""
        self.server.telemetry.reset()
        for k in self.server.queue.counters:
            self.server.queue.counters[k] = 0

    # -- observability (same surface RemoteHost serves over rpc) -------------

    def metrics_text(self, prefix: str = "aidw") -> str:
        """Prometheus text exposition of this host's metric registry."""
        return self.server.metrics_text(prefix)

    def metrics_snapshot(self) -> dict:
        """JSON snapshot of this host's metric registry."""
        return self.server.metrics_snapshot()

    def spans(self, drain: bool = True) -> list[dict]:
        """This host's finished span dicts ([] when tracing is off)."""
        return self.server.spans(drain=drain)

    def debugz(self) -> dict:
        """This host's diagnostics bundle (queue/epoch position, registry
        state, SLO evaluation, flight-recorder traces) stamped with the
        FLEET host identity — the server's internal host_id and the fleet's
        can differ for process-backed hosts."""
        bundle = self.server.debugz()
        bundle["host_id"] = self.host_id
        return bundle

    def close(self, timeout: float | None = 30.0) -> None:
        self.server.close(timeout=timeout)

    def __repr__(self) -> str:
        return f"HostServer(host_id={self.host_id}, epoch={self.epoch})"
