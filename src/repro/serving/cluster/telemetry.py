"""Fleet telemetry: merge per-host serving reports into one cluster view.

Each host's :meth:`repro.serving.server.AsyncAidwServer.report` carries a
``merge`` block — the full :meth:`repro.serving.telemetry.Telemetry.state`
with per-axis histogram BIN COUNTS, not just percentile snapshots.  Fleet
percentiles are computed by summing those bins and re-reading the quantiles
(:meth:`repro.serving.telemetry.LatencyHistogram.from_states`): averaging
per-host p99s has no statistical meaning, merging the histograms is exact
(up to the shared log-bin resolution).

Throughput: per-host monotonic clocks are not comparable across processes,
so fleet QPS is the SUM of per-host rates (each over its own observed
window) — rates add, timestamps don't travel.

Counter conventions: everything integer in the per-host report
(``submitted``/``completed``/``shed``/``rejected_full``/``overflow_queries``
/admission counters/...) sums across hosts; ``epoch`` reports the
fleet-wide min/max so a stalled host (epoch lagging the fleet) is visible
at a glance.
"""

from __future__ import annotations

from ..telemetry import LatencyHistogram

__all__ = ["merge_reports"]

_AXES = ("queue", "execute", "total", "shed")


def merge_reports(host_reports: list[dict]) -> dict:
    """Merge per-host ``AsyncAidwServer.report()`` dicts (each carrying the
    ``merge`` state block) into one fleet report: summed counters, exact
    merged-histogram p50/p95/p99 per latency axis, summed QPS, the fleet
    epoch range, and an ``ingest`` block (summed staged bytes/compactions,
    max ring occupancy / tombstone fraction) from per-host session stats.  JSON-serializable (the ``load_gen.py --cluster
    --json`` artifact body)."""
    if not host_reports:
        raise ValueError("merge_reports needs at least one host report")
    counters: dict = {}
    admission: dict = {}
    qps = 0.0
    epochs = []
    host_ids = []
    # ingest tier: bytes/compactions/slab touches SUM across hosts; ring
    # occupancy and tombstone fraction take the fleet MAX (the host closest
    # to its compaction high-water / rebin threshold is the one that matters)
    _ING_SUM = ("staged_bytes_total", "compactions", "slabs_touched",
                "full_restages", "spilled_updates", "ring_points")
    _ING_MAX = ("ring_occupancy", "tombstone_frac")
    ingest: dict = {}
    for rep in host_reports:
        st = rep["merge"]
        for k, v in st["counters"].items():
            counters[k] = counters.get(k, 0) + int(v)
        for k, v in rep.get("admission", {}).items():
            admission[k] = admission.get(k, 0) + int(v)
        sess = rep.get("session", {})
        for k in _ING_SUM:
            if k in sess:
                ingest[k] = ingest.get(k, 0) + int(sess[k])
        for k in _ING_MAX:
            if k in sess:
                ingest[k] = max(ingest.get(k, 0.0), float(sess[k]))
        qps += float(st["queries_per_s"])
        epochs.append(int(rep.get("epoch", 0)))
        host_ids.append(rep.get("host_id"))
    latency = {}
    for axis in _AXES:
        merged = LatencyHistogram.from_states(
            rep["merge"]["hists"][axis] for rep in host_reports)
        latency[axis] = merged.snapshot()
    return {
        **counters,
        "hosts": len(host_reports),
        "host_ids": host_ids,
        "queries_per_s": qps,
        "latency": latency,
        "admission": admission,
        "ingest": ingest,
        "epoch_min": min(epochs),
        "epoch_max": max(epochs),
    }
