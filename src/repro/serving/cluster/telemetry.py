"""Fleet telemetry: merge per-host serving reports into one cluster view.

Each host's :meth:`repro.serving.server.AsyncAidwServer.report` carries a
``merge`` block — the full :meth:`repro.serving.telemetry.Telemetry.state`
with per-axis histogram BIN COUNTS, not just percentile snapshots.  Fleet
percentiles are computed by summing those bins and re-reading the quantiles
(:meth:`repro.serving.telemetry.LatencyHistogram.from_states`): averaging
per-host p99s has no statistical meaning, merging the histograms is exact
(up to the shared log-bin resolution).

Throughput: per-host monotonic clocks are not comparable across processes,
but each host's :meth:`~repro.serving.telemetry.Telemetry.state` carries a
WALL-anchored throughput window, so fleet QPS is computed over the UNION
wall window — ``sum(queries) / (max(t1_wall) - min(t0_wall))``.  Summing
per-host rates (the pre-PR-8 behaviour, kept as the fallback when a report
lacks windows) over-reports whenever host windows only partially overlap:
two hosts that each served 100 q/s for DIFFERENT halves of a second did
100 q/s fleet-wide, not 200.  The summed rate survives in the report as
``queries_per_s_summed`` so the drift itself is observable.

Counter conventions: everything integer in the per-host report
(``submitted``/``completed``/``shed``/``rejected_full``/``overflow_queries``
/admission counters/...) sums across hosts; ``epoch`` reports the
fleet-wide min/max so a stalled host (epoch lagging the fleet) is visible
at a glance.  Reports carrying a ``registry`` block (PR 8) additionally
merge into one fleet :class:`repro.obs.Registry` — counters add, gauges
combine per their declared merge mode, histograms merge bin-exact — whose
snapshot lands under ``stages``.
"""

from __future__ import annotations

from ...obs import Registry
from ..telemetry import LatencyHistogram

__all__ = ["merge_reports"]

_AXES = ("queue", "execute", "total", "shed")


def merge_reports(host_reports: list[dict]) -> dict:
    """Merge per-host ``AsyncAidwServer.report()`` dicts (each carrying the
    ``merge`` state block) into one fleet report: summed counters, exact
    merged-histogram p50/p95/p99 per latency axis, summed QPS, the fleet
    epoch range, and an ``ingest`` block (summed staged bytes/compactions,
    max ring occupancy / tombstone fraction) from per-host session stats.  JSON-serializable (the ``load_gen.py --cluster
    --json`` artifact body)."""
    if not host_reports:
        raise ValueError("merge_reports needs at least one host report")
    counters: dict = {}
    admission: dict = {}
    qps_summed = 0.0
    windows = []            # wall-anchored per-host throughput windows
    epochs = []
    host_ids = []
    # ingest tier: bytes/compactions/slab touches SUM across hosts; ring
    # occupancy and tombstone fraction take the fleet MAX (the host closest
    # to its compaction high-water / rebin threshold is the one that matters)
    _ING_SUM = ("staged_bytes_total", "compactions", "slabs_touched",
                "full_restages", "spilled_updates", "ring_points")
    _ING_MAX = ("ring_occupancy", "tombstone_frac")
    ingest: dict = {}
    for rep in host_reports:
        st = rep["merge"]
        for k, v in st["counters"].items():
            counters[k] = counters.get(k, 0) + int(v)
        for k, v in rep.get("admission", {}).items():
            admission[k] = admission.get(k, 0) + int(v)
        sess = rep.get("session", {})
        for k in _ING_SUM:
            if k in sess:
                ingest[k] = ingest.get(k, 0) + int(sess[k])
        for k in _ING_MAX:
            if k in sess:
                ingest[k] = max(ingest.get(k, 0.0), float(sess[k]))
        qps_summed += float(st["queries_per_s"])
        w = st.get("window")
        if w is not None and w.get("t0_wall") is not None:
            windows.append(w)
        epochs.append(int(rep.get("epoch", 0)))
        host_ids.append(rep.get("host_id"))
    if windows:
        # union wall window: hosts that served nothing carry no window and
        # (correctly) contribute zero queries and zero width
        t0 = min(w["t0_wall"] for w in windows)
        t1 = max(w["t1_wall"] for w in windows)
        qps = sum(int(w["queries"]) for w in windows) / max(t1 - t0, 1e-9)
    else:
        qps = qps_summed            # legacy reports / idle fleet
    latency = {}
    for axis in _AXES:
        merged = LatencyHistogram.from_states(
            rep["merge"]["hists"][axis] for rep in host_reports)
        latency[axis] = merged.snapshot()
    out = {
        **counters,
        "hosts": len(host_reports),
        "host_ids": host_ids,
        "queries_per_s": qps,
        "queries_per_s_summed": qps_summed,
        "latency": latency,
        "admission": admission,
        "ingest": ingest,
        "epoch_min": min(epochs),
        "epoch_max": max(epochs),
    }
    reg_states = [rep["registry"] for rep in host_reports
                  if "registry" in rep]
    if reg_states:
        out["stages"] = Registry.merge_states(reg_states).snapshot()
    return out
