"""Multi-host AIDW serving cluster: epoch-ordered updates, query routing,
and fleet telemetry.

One fleet = N host processes (or N in-process hosts), each a
:class:`~repro.serving.cluster.host.HostServer` — a full dataset replica
behind its own :class:`repro.serving.server.AsyncAidwServer` with a
shard-local admission queue, serving queries on that host's local devices.
Scaling follows the decomposition in Gowanlock's hybrid CPU/GPU KNN-join
work: kNN query throughput scales by partitioning *query* work across
executors, while each executor keeps an efficient local index — here the
paper's grid-binned CSR table, replicated per host and kept consistent by
the epoch protocol below.

**The epoch protocol** (mechanics in ``cluster/epochs.py``): every
``update_dataset`` is assigned a monotonically increasing epoch by the one
:class:`~repro.serving.cluster.epochs.EpochCoordinator` and broadcast to
every live host while the coordinator holds its broadcast lock, so the
update occupies the same position in every host's FIFO admission stream
relative to the routed queries; each host's
:class:`~repro.serving.cluster.epochs.EpochApplier` then admits updates to
the local server strictly in epoch order (buffering transport stragglers,
dropping duplicates).  On each host the update is the same FIFO barrier
the single-process worker already provides — applied between batches,
never racing the CSR table.

**Consistency contract**: every host applies the same updates in the same
epoch order; a query routed to any host is served against some epoch ``k``
— the same dataset state a single ``AsyncAidwServer`` would reach after
applying epochs ``1..k`` in order — with ``k >= `` the newest epoch whose
broadcast completed before the query was routed.  Served requests are
stamped with their epoch (``InterpolationRequest.epoch``), which is the
testable witness: the cluster suite asserts bit-identical results against
a single server replaying the coordinator's epoch log.

Read path: the :class:`~repro.serving.cluster.router.Router` spreads
traffic round-robin or by shard-local queue depth, drains hosts on
heartbeat timeout or in-band failure (reusing
:class:`repro.runtime.fault_tolerance.HeartbeatMonitor`), and resubmits a
drained host's unserved requests to survivors — exactly-once client-
visible results over at-least-once execution (safe: queries are read-only
against epoch-consistent replicas).

Telemetry: per-host log-binned latency histograms merge bin-by-bin into
fleet p50/p95/p99 + summed QPS (``cluster/telemetry.py``) — the
``benchmarks/load_gen.py --cluster --json`` fleet artifact.

Fleet data partitioning (first cut, PR 5):
:class:`~repro.serving.cluster.fleet.ShardedAidwCluster` serves a dataset
too large to replicate by row-slab-sharding the points across hosts
(:func:`~repro.serving.cluster.fleet.fleet_partition` — the grid-aware slab
decomposition as the partitioning backbone) and fanning each query batch
out to every shard with a client-side k-way merge: per-shard grid-kNN
heaps merge into the global top-k (-> adaptive alpha), then per-shard
Eq. (1) partial sums add up to the global interpolation.  Shard ops are
epoch-stamped and FIFO-serialized with updates on each host, so a merged
batch always reflects one consistent epoch.

Entry points: :class:`~repro.serving.cluster.fleet.AidwCluster` (in-process
fleet or pre-built hosts), :func:`~repro.serving.cluster.bootstrap
.bootstrap` + ``python -m repro.serving.cluster.rpc`` (process-backed
fleet over the socket control plane, optionally ``jax.distributed``;
``--shard-of N`` serves one shard of the partitioned fleet).
"""

from .bootstrap import ClusterConfig, ClusterContext, bootstrap, local_mesh
from .epochs import EpochApplier, EpochCoordinator, EpochUpdate, UpdateHandle
from .fleet import AidwCluster, ShardedAidwCluster, fleet_partition
from .host import HostServer
from .router import NoLiveHosts, RoutedRequest, Router
from .rpc import RemoteHost, serve_host, spawn_worker
from .telemetry import merge_reports

__all__ = [
    "AidwCluster", "ShardedAidwCluster", "fleet_partition", "ClusterConfig",
    "ClusterContext", "bootstrap",
    "local_mesh", "EpochApplier", "EpochCoordinator", "EpochUpdate",
    "UpdateHandle", "HostServer", "NoLiveHosts", "RoutedRequest", "Router",
    "RemoteHost", "serve_host", "spawn_worker", "merge_reports",
]
