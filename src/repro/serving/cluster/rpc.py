"""Process-to-process control plane for the serving fleet (stdlib only).

The data plane is per-host (each process serves queries on its own devices
against its own replica), so the only cross-process traffic is control:
query routing, epoch-tagged update broadcast, health probes, and telemetry
pulls.  That traffic is small and latency-tolerant, so the transport is
deliberately simple — one TCP connection per (coordinator, host) pair,
newline-delimited JSON messages with base64-encoded ndarrays, correlation
ids for request/response matching, and a reader thread per side:

* coordinator side — :class:`RemoteHost`, a proxy implementing the
  :class:`repro.serving.cluster.host.HostServer` surface, so the router
  and :class:`~repro.serving.cluster.fleet.AidwCluster` cannot tell a
  remote host from a local one.  Blocking calls (``wait``/``flush``/
  ``wait_update``) multiplex over the one connection via correlation ids.
* host side — :func:`serve_host`, a dispatch loop around one local
  :class:`HostServer`.  Blocking ops run on their own threads so a slow
  ``await`` never stalls heartbeat probes; socket writes are serialized
  by a lock.

Epoch ordering over this transport is free: a TCP connection is FIFO and
each host has exactly one update source (the coordinator), so updates
arrive in broadcast epoch order; the host-side
:class:`~repro.serving.cluster.epochs.EpochApplier` still verifies it.

Array payloads round-trip bit-exactly (raw little-endian bytes, base64),
which the cluster's bit-identity guarantee depends on.

``main()`` is the worker-process entry point::

    python -m repro.serving.cluster.rpc --host-id 1 --n-hosts 2 \
        --points 16384 --seed 0 [--jax-coordinator 127.0.0.1:29801]

:func:`spawn_worker` launches exactly that as a subprocess (the load
generator's ``--cluster-procs`` mode and the CI cluster-suite tests).
"""

from __future__ import annotations

import base64
import itertools
import json
import os
import socket
import subprocess
import sys
import threading
import time

import numpy as np

from ..queue import AdmissionQueueFull
from .bootstrap import ClusterConfig, bootstrap
from .epochs import EpochUpdate, UpdateHandle
from .host import HostServer

__all__ = ["RemoteHost", "RemoteRequest", "serve_host", "spawn_worker",
           "connect_with_retry", "free_port_base"]


# -- wire format -------------------------------------------------------------


def enc_array(a) -> dict | None:
    if a is None:
        return None
    a = np.ascontiguousarray(a)
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "b64": base64.b64encode(a.tobytes()).decode("ascii")}


def dec_array(d) -> np.ndarray | None:
    if d is None:
        return None
    # copy: frombuffer views are read-only, and decoded arrays flow into
    # code (delta rebinning) that expects ordinary writable ndarrays
    return np.frombuffer(base64.b64decode(d["b64"]),
                         dtype=np.dtype(d["dtype"])).reshape(d["shape"]).copy()


def _send(wfile, wlock, obj: dict) -> None:
    data = (json.dumps(obj) + "\n").encode()
    with wlock:
        wfile.write(data)
        wfile.flush()


# -- coordinator side --------------------------------------------------------


class RemoteRequest:
    """Coordinator-side stand-in for a request living on a remote host."""

    def __init__(self, uid: int, queries_xy):
        self.uid = uid
        self.queries_xy = queries_xy
        self.status = "queued"
        self.done = False
        self.values = None
        self.overflow = 0
        self.epoch: int | None = None


class RemoteHost:
    """Proxy for a :class:`HostServer` in another process.

    Implements the same surface (submit/wait/submit_update/wait_update/
    queue_depth/flush/report/reset_telemetry/close); any transport failure
    raises RuntimeError, which the router treats as host death (drain).
    """

    def __init__(self, host_id, address: tuple[str, int], *,
                 connect_timeout_s: float = 60.0):
        self.host_id = host_id
        self._sock = connect_with_retry(address, connect_timeout_s)
        self._rfile = self._sock.makefile("rb")
        self._wfile = self._sock.makefile("wb")
        self._wlock = threading.Lock()
        self._mid = itertools.count()
        self._pending: dict[int, list] = {}    # mid -> [event, reply|None]
        self._plock = threading.Lock()
        self._dead: BaseException | None = None
        self._reader = threading.Thread(target=self._read_loop,
                                        name=f"rpc-reader-{host_id}",
                                        daemon=True)
        self._reader.start()

    # transport --------------------------------------------------------------

    def _read_loop(self) -> None:
        try:
            for line in self._rfile:
                msg = json.loads(line)
                with self._plock:
                    slot = self._pending.pop(msg.get("id"), None)
                if slot is not None:
                    slot[1] = msg
                    slot[0].set()
        except Exception as e:
            self._dead = e
        finally:
            self._dead = self._dead or ConnectionError("rpc stream closed")
            with self._plock:
                for ev, _ in self._pending.values():
                    ev.set()
                self._pending.clear()

    def _call(self, op: str, timeout: float | None = None, **fields) -> dict:
        if self._dead is not None:
            raise RuntimeError(
                f"remote host {self.host_id} unreachable") from self._dead
        mid = next(self._mid)
        slot = [threading.Event(), None]
        with self._plock:
            self._pending[mid] = slot
        try:
            _send(self._wfile, self._wlock, {"op": op, "id": mid, **fields})
        except Exception as e:
            with self._plock:
                self._pending.pop(mid, None)
            raise RuntimeError(
                f"remote host {self.host_id} unreachable") from e
        if not slot[0].wait(timeout):
            with self._plock:
                self._pending.pop(mid, None)
            # TRANSPORT timeout, not a remote "not done yet" (those come
            # back as {"timeout": true} replies well inside the padded
            # bound): the host is frozen or the link is gone — raise the
            # error class the router treats as host death, so a hung host
            # gets drained instead of heartbeat-fed forever
            raise RuntimeError(f"rpc {op} to host {self.host_id} got no "
                               f"response in {timeout}s (host hung?)")
        reply = slot[1]
        if reply is None:
            raise RuntimeError(
                f"remote host {self.host_id} unreachable") from self._dead
        if reply.get("error"):
            raise _remote_error(reply)
        return reply

    # HostServer surface -----------------------------------------------------

    def submit(self, queries_xy, *, deadline_s: float | None = None,
               uid: int | None = None, timeout: float | None = None,
               trace_id: str | None = None,
               parent_span: str | None = None) -> RemoteRequest:
        """``timeout`` bounds remote admission (a full queue raises
        :class:`~repro.serving.queue.AdmissionQueueFull` from the host,
        re-raised here by type) — without it a backpressured host would
        blow the transport bound and read as dead.  ``trace_id``/
        ``parent_span`` ride the wire so the remote host's serving spans
        join the router's trace."""
        q = np.asarray(queries_xy)
        reply = self._call("submit",
                           timeout=30.0 if timeout is None else timeout + 30.0,
                           q=enc_array(q), deadline_s=deadline_s, uid=uid,
                           wait_s=timeout, trace_id=trace_id,
                           parent_span=parent_span)
        req = RemoteRequest(reply["uid"], q)
        if reply.get("status") == "shed":      # shed on arrival remotely
            req.status, req.done = "shed", True
        return req

    def wait(self, req: RemoteRequest,
             timeout: float | None = None) -> RemoteRequest:
        if req.done:
            return req
        # the remote side bounds its own wait; pad the transport timeout so
        # a response that IS coming isn't cut off mid-flight
        reply = self._call("await", timeout=None if timeout is None
                           else timeout + 30.0, uid=req.uid, wait_s=timeout)
        if reply.get("timeout"):
            raise TimeoutError(f"request {req.uid} not done on host "
                               f"{self.host_id} after {timeout}s")
        req.status = reply["status"]
        req.done = True
        req.values = dec_array(reply.get("values"))
        req.overflow = int(reply.get("overflow", 0))
        req.epoch = reply.get("epoch")
        return req

    def submit_update(self, upd: EpochUpdate) -> UpdateHandle:
        handle = UpdateHandle(upd.epoch)
        try:
            reply = self._call(
                "update", timeout=60.0, epoch=upd.epoch,
                points=enc_array(upd.points_xyz),
                inserts=enc_array(upd.inserts),
                deletes=enc_array(None if upd.deletes is None
                                  else np.asarray(upd.deletes)),
                compact=int(upd.compact), trace_id=upd.trace_id,
                parent_span=upd.parent_span)
            handle.duplicate = bool(reply.get("duplicate"))
            handle._bound.set()
        except BaseException as e:
            handle._fail(e)
        return handle

    def wait_update(self, handle: UpdateHandle,
                    timeout: float | None = None) -> None:
        if handle.error is not None:
            raise handle.error
        if handle.duplicate:
            return
        reply = self._call("update_wait", timeout=None if timeout is None
                           else timeout + 30.0, epoch=handle.epoch,
                           wait_s=timeout)
        if reply.get("timeout"):
            raise TimeoutError(f"epoch {handle.epoch} not applied on host "
                               f"{self.host_id} after {timeout}s")

    def shard_knn(self, queries_xy, *, timeout: float | None = None):
        # like wait()/wait_update(): an unbounded caller wait must not be
        # cut off by a transport cap (a cold shard's first-bucket compile
        # can far outlast any fixed bound on the CPU CI mesh)
        reply = self._call(
            "shard_knn", timeout=None if timeout is None else timeout + 30.0,
            q=enc_array(np.asarray(queries_xy)), wait_s=timeout)
        return (dec_array(reply["d2"]), dec_array(reply["z"]),
                dec_array(reply["overflow"]), reply.get("epoch"))

    def shard_partial(self, queries_xy, alpha, *,
                      timeout: float | None = None):
        reply = self._call(
            "shard_partial",
            timeout=None if timeout is None else timeout + 30.0,
            q=enc_array(np.asarray(queries_xy)),
            alpha=enc_array(np.asarray(alpha)), wait_s=timeout)
        return (dec_array(reply["swz"]), dec_array(reply["sw"]),
                reply.get("epoch"))

    def prewarm(self, wait: bool = True,
                timeout: float | None = None) -> dict:
        """Fleet control-plane prewarm: AOT-compile + warm the remote
        host's whole bucket ladder before it enters rotation.  Like
        wait()/flush(), the caller's bound rides as ``wait_s`` and the
        transport timeout gets slack on top — an unbounded prewarm (cold
        CPU CI ladder) must not be cut off by a transport cap."""
        reply = self._call(
            "prewarm", timeout=None if timeout is None else timeout + 30.0,
            wait=int(bool(wait)), wait_s=timeout)
        return reply["status"]

    @property
    def epoch(self) -> int:
        return int(self._call("epoch", timeout=30.0)["epoch"])

    def queue_depth(self) -> int:
        return int(self._call("depth", timeout=30.0)["depth"])

    def probe(self) -> int:
        """Active liveness probe (router ``check()``): raises when the host
        process is gone, hung, or its worker died; else the queue depth."""
        return int(self._call("probe", timeout=30.0)["depth"])

    def flush(self, timeout: float | None = None) -> None:
        self._call("flush", timeout=None if timeout is None
                   else timeout + 30.0, wait_s=timeout)

    def report(self) -> dict:
        return self._call("report", timeout=60.0)["report"]

    def metrics_text(self, prefix: str = "aidw") -> str:
        """Prometheus text exposition pulled from the remote host."""
        return self._call("metrics", timeout=60.0, prefix=prefix)["text"]

    def metrics_snapshot(self) -> dict:
        """Remote host's registry snapshot (JSON)."""
        return self._call("metrics", timeout=60.0)["snapshot"]

    def spans(self, drain: bool = True) -> list[dict]:
        """Pull the remote host's finished span dicts (the cross-process
        trace collection hook; ``drain=True`` empties the remote buffer)."""
        return self._call("spans", timeout=60.0, drain=int(drain))["spans"]

    def debugz(self) -> dict:
        """Pull the remote host's diagnostics bundle (queue/epoch position,
        registry state, SLO evaluation, flight-recorder traces).  The
        bundle is JSON by construction, so it rides the control plane
        as-is."""
        return self._call("debugz", timeout=60.0)["bundle"]

    def reset_telemetry(self) -> None:
        self._call("reset", timeout=30.0)

    def close(self, timeout: float | None = 30.0) -> None:
        try:
            self._call("close", timeout=timeout, wait_s=timeout)
        except (RuntimeError, TimeoutError):
            pass                               # already gone is fine
        try:
            self._sock.close()
        except OSError:
            pass


class _RemoteCallError(RuntimeError):
    """An exception raised ON the remote host, re-raised here by type name."""


def _remote_error(reply: dict):
    kind = reply.get("error_type", "")
    msg = f"[host] {reply['error']}"
    # AdmissionQueueFull must survive the wire: the router treats it as
    # backpressure (try another host), anything unrecognized as host death
    for cls in (TimeoutError, ValueError, KeyError, IndexError,
                AdmissionQueueFull):
        if kind == cls.__name__:
            return cls(msg)
    return _RemoteCallError(f"{kind}: {msg}")


def free_port_base(n_hosts: int = 1) -> int:
    """A base control port whose worker slots ``base+1 .. base+n_hosts-1``
    are all bindable RIGHT NOW (best effort: another process can still
    grab one before the worker does, but an already-taken port is caught
    here instead of as a connect timeout minutes later)."""
    for _ in range(64):
        probe = socket.socket()
        probe.bind(("127.0.0.1", 0))
        base = probe.getsockname()[1]
        probe.close()
        try:
            for i in range(1, n_hosts):
                s = socket.create_server(("127.0.0.1", base + i))
                s.close()
            return base
        except OSError:
            continue
    raise OSError(f"no block of {n_hosts} consecutive free ports found")


def connect_with_retry(address: tuple[str, int],
                       timeout_s: float = 60.0) -> socket.socket:
    """Dial until the host process is listening (it may still be compiling
    its session when the coordinator comes up)."""
    deadline = time.monotonic() + timeout_s
    last: Exception | None = None
    while time.monotonic() < deadline:
        try:
            return socket.create_connection(address, timeout=10.0)
        except OSError as e:
            last = e
            time.sleep(0.2)
    raise ConnectionError(
        f"could not reach fleet host at {address} after {timeout_s}s"
    ) from last


# -- host side ---------------------------------------------------------------


def serve_host(host: HostServer, address: tuple[str, int], *,
               ready_event: threading.Event | None = None) -> None:
    """Serve one coordinator connection until EOF or a ``close`` op.

    Listens on ``address``, accepts exactly one connection (the
    coordinator), and dispatches messages; every op that can block —
    waits, flushes, close, and the enqueueing ops (``submit``/``update``
    block under admission-queue backpressure) — runs on its own thread so
    the loop keeps answering ``depth`` probes while work is in flight.
    """
    lsock = socket.create_server(address)
    if ready_event is not None:
        ready_event.set()
    conn, _ = lsock.accept()
    lsock.close()
    rfile = conn.makefile("rb")
    wfile = conn.makefile("wb")
    wlock = threading.Lock()
    stop = threading.Event()
    # uid -> request object (awaits need the object; flush() reaps it from
    # the server registry, so the rpc layer keeps its own map)
    requests: dict[int, object] = {}
    updates: dict[int, UpdateHandle] = {}
    rlock = threading.Lock()

    def reply(mid: int, **fields) -> None:
        try:
            _send(wfile, wlock, {"id": mid, **fields})
        except OSError:
            stop.set()

    def fail(mid: int, e: BaseException) -> None:
        reply(mid, error=str(e), error_type=type(e).__name__)

    def handle(msg: dict) -> None:
        mid, op = msg["id"], msg["op"]
        try:
            if op == "submit":
                req = host.submit(dec_array(msg["q"]),
                                  deadline_s=msg.get("deadline_s"),
                                  uid=msg.get("uid"),
                                  timeout=msg.get("wait_s"),
                                  trace_id=msg.get("trace_id"),
                                  parent_span=msg.get("parent_span"))
                if not req.done:
                    # shed-on-arrival requests are terminal in this reply
                    # and never awaited — registering them would leak one
                    # query array per shed request for the worker lifetime
                    with rlock:
                        requests[req.uid] = req
                reply(mid, uid=req.uid, status=req.status)
            elif op == "await":
                with rlock:
                    req = requests.get(msg["uid"])
                if req is None:
                    raise KeyError(f"unknown uid {msg['uid']}")
                try:
                    host.wait(req, timeout=msg.get("wait_s"))
                except TimeoutError:
                    reply(mid, timeout=True)
                    return
                with rlock:
                    requests.pop(msg["uid"], None)
                reply(mid, status=req.status, values=enc_array(req.values),
                      overflow=req.overflow,
                      epoch=getattr(req, "epoch", None))
            elif op == "update":
                upd = EpochUpdate(epoch=int(msg["epoch"]),
                                  points_xyz=dec_array(msg.get("points")),
                                  inserts=dec_array(msg.get("inserts")),
                                  deletes=dec_array(msg.get("deletes")),
                                  compact=bool(msg.get("compact", 0)),
                                  trace_id=msg.get("trace_id"),
                                  parent_span=msg.get("parent_span"))
                h = host.submit_update(upd)
                if not h.duplicate:
                    # duplicates are never waited on (and must not clobber
                    # a pending original handle for the same epoch)
                    with rlock:
                        updates[upd.epoch] = h
                reply(mid, ok=1, duplicate=h.duplicate)
            elif op == "update_wait":
                with rlock:
                    h = updates.get(int(msg["epoch"]))
                if h is None:
                    raise KeyError(f"epoch {msg['epoch']} never offered")
                try:
                    host.wait_update(h, timeout=msg.get("wait_s"))
                except TimeoutError:
                    # the timed-out wait WITHDREW the op (epoch gap; the
                    # coordinator drains this host) — the handle is spent,
                    # keeping it would leak one entry per timed-out epoch
                    with rlock:
                        updates.pop(int(msg["epoch"]), None)
                    reply(mid, timeout=True)
                    return
                with rlock:
                    updates.pop(int(msg["epoch"]), None)
                reply(mid, ok=1)
            elif op == "shard_knn":
                d2, z, ovf, epoch = host.shard_knn(dec_array(msg["q"]),
                                                   timeout=msg.get("wait_s"))
                reply(mid, d2=enc_array(d2), z=enc_array(z),
                      overflow=enc_array(ovf), epoch=epoch)
            elif op == "shard_partial":
                swz, sw, epoch = host.shard_partial(
                    dec_array(msg["q"]), dec_array(msg["alpha"]),
                    timeout=msg.get("wait_s"))
                reply(mid, swz=enc_array(swz), sw=enc_array(sw), epoch=epoch)
            elif op == "prewarm":
                reply(mid, status=host.prewarm(
                    wait=bool(msg.get("wait", 1)),
                    timeout=msg.get("wait_s")))
            elif op == "depth":
                reply(mid, depth=host.queue_depth())
            elif op == "probe":
                reply(mid, depth=host.probe())
            elif op == "epoch":
                reply(mid, epoch=host.epoch)
            elif op == "flush":
                host.flush(timeout=msg.get("wait_s"))
                reply(mid, ok=1)
            elif op == "report":
                reply(mid, report=host.report())
            elif op == "metrics":
                reply(mid, text=host.metrics_text(msg.get("prefix", "aidw")),
                      snapshot=host.metrics_snapshot())
            elif op == "spans":
                reply(mid, spans=host.spans(drain=bool(msg.get("drain", 1))))
            elif op == "debugz":
                # diagnostics: inline like report/metrics/spans — never
                # behind the blocking set, so a wedged worker still answers
                reply(mid, bundle=host.debugz())
            elif op == "reset":
                host.reset_telemetry()
                reply(mid, ok=1)
            elif op == "close":
                host.close(timeout=msg.get("wait_s"))
                reply(mid, ok=1)
                stop.set()
                # unblock the dispatch loop's readline — the coordinator
                # may keep its socket half open after the close ack
                try:
                    conn.shutdown(socket.SHUT_RD)
                except OSError:
                    pass
            else:
                raise ValueError(f"unknown rpc op {op!r}")
        except BaseException as e:           # noqa: BLE001 — surface to peer
            fail(mid, e)

    # submit/update can block on a FULL admission queue (backpressure), so
    # they leave the dispatch loop too — a backpressured-but-healthy host
    # must keep answering depth probes or the router drains it.  Enqueue
    # ORDER is still caller-pinned: every enqueueing op replies only after
    # the item is in the FIFO, and callers block on that reply before
    # issuing their next op.
    _BLOCKING = {"await", "flush", "update_wait", "close", "submit",
                 "update", "shard_knn", "shard_partial", "prewarm"}
    try:
        while not stop.is_set():
            line = rfile.readline()
            if not line:
                break
            msg = json.loads(line)
            if msg["op"] in _BLOCKING:
                threading.Thread(target=handle, args=(msg,),
                                 daemon=True).start()
            else:
                handle(msg)
    finally:
        try:
            conn.close()
        except OSError:
            pass


# -- worker-process entry point ----------------------------------------------


def spawn_worker(host_id: int, n_hosts: int, *, points: int, seed: int = 0,
                 control_port: int = 29900, max_batch: int = 4096,
                 query_domain_n: int = 1024,
                 jax_coordinator: str | None = None,
                 shard_of: int = 0,
                 trace_sample_rate: float | None = None,
                 compilation_cache_dir: str | None = None,
                 env: dict | None = None) -> subprocess.Popen:
    """Launch one fleet host as a subprocess running :func:`main`.

    ``shard_of=N`` makes the worker serve shard ``host_id`` of an N-way
    :func:`~repro.serving.cluster.fleet.fleet_partition` of the
    reconstructed dataset instead of a full replica (the
    :class:`~repro.serving.cluster.fleet.ShardedAidwCluster` deployment
    shape)."""
    # -c instead of -m: runpy re-executing a module the package __init__
    # already imported would warn (and double-define the rpc classes)
    cmd = [sys.executable, "-c",
           "import sys; from repro.serving.cluster.rpc import main; "
           "main(sys.argv[1:])",
           "--host-id", str(host_id), "--n-hosts", str(n_hosts),
           "--points", str(points), "--seed", str(seed),
           "--control-port", str(control_port),
           "--max-batch", str(max_batch),
           "--query-domain", str(query_domain_n)]
    if shard_of:
        cmd += ["--shard-of", str(shard_of)]
    if jax_coordinator:
        cmd += ["--jax-coordinator", jax_coordinator]
    if trace_sample_rate is not None:
        cmd += ["--trace-sample-rate", str(trace_sample_rate)]
    if compilation_cache_dir:
        cmd += ["--compilation-cache-dir", compilation_cache_dir]
    return subprocess.Popen(cmd, env=env)


def main(argv=None) -> None:
    import argparse

    from repro.data.pipeline import spatial_points, spatial_queries

    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--host-id", type=int, required=True)
    p.add_argument("--n-hosts", type=int, required=True)
    p.add_argument("--points", type=int, default=16384)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--control-host", default="127.0.0.1")
    p.add_argument("--control-port", type=int, default=29900)
    p.add_argument("--max-batch", type=int, default=4096)
    p.add_argument("--query-domain", type=int, default=1024,
                   help="query_domain sample count (0 = none); seed fixed "
                        "at 1 so every fleet host plans the same grid")
    p.add_argument("--jax-coordinator", default=None,
                   help="host:port for jax.distributed.initialize "
                        "(omit for a transport-only fleet)")
    p.add_argument("--shard-of", type=int, default=0, metavar="N",
                   help="serve shard <host-id> of an N-way fleet_partition "
                        "of the dataset instead of a full replica")
    p.add_argument("--trace-sample-rate", type=float, default=None,
                   help="obs trace sampling probability for this host "
                        "(omit = tracing off; spans pull over the 'spans' "
                        "rpc op)")
    p.add_argument("--compilation-cache-dir", default=None,
                   help="persistent XLA compilation cache directory "
                        "(default: AIDW_CACHE_DIR env; hosts given the "
                        "same directory share one cache)")
    args = p.parse_args(argv)

    ctx = bootstrap(ClusterConfig(
        n_hosts=args.n_hosts, host_id=args.host_id,
        jax_coordinator=args.jax_coordinator,
        control_host=args.control_host, control_port=args.control_port,
        cache_dir=(args.compilation_cache_dir
                   or os.environ.get("AIDW_CACHE_DIR") or None)))
    # the dataset replica is reconstructed, not shipped: spatial_points is
    # deterministic in (n, seed), so every host plans the identical grid
    pts = spatial_points(args.points, seed=args.seed)
    qd = spatial_queries(args.query_domain, seed=1) \
        if args.query_domain else None
    if args.shard_of:
        # deterministic partition: the coordinator computes the identical
        # split from the same (n, seed, query_domain) inputs
        from .fleet import fleet_partition

        _, _, members = fleet_partition(pts, args.shard_of,
                                        query_domain=qd)
        pts = pts[members[ctx.host_id]]
    host = HostServer(ctx.host_id, pts, max_batch=args.max_batch,
                      query_domain=qd, mesh=ctx.mesh,
                      trace_sample_rate=args.trace_sample_rate)
    serve_host(host, ctx.cfg.control_address(ctx.host_id))
    # joins the fleet-wide shutdown barrier — the coordinator side calls
    # ctx.shutdown() after closing its proxies, and a worker that skipped
    # it would be declared dead and crash every other fleet process
    ctx.shutdown()


if __name__ == "__main__":
    main()
