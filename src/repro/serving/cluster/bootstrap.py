"""Process bootstrap for the multi-host AIDW serving fleet.

One serving fleet is N host *processes* (plus, degenerately, N in-process
hosts for tests and single-machine runs).  Each process calls
:func:`bootstrap` once at startup to learn

* its identity — ``host_id`` in ``[0, n_hosts)`` (host 0 is the
  coordinator: it owns the :class:`~repro.serving.cluster.epochs
  .EpochCoordinator` and the query :class:`~repro.serving.cluster.router
  .Router`),
* its **local** device mesh — the data plane is deliberately per-host
  (every host serves queries against its own dataset replica on its own
  devices; consistency comes from the epoch protocol, not from cross-host
  collectives), so the mesh is built over ``jax.local_devices()`` only,
* whether ``jax.distributed`` is active — when a coordinator address is
  given the runtime is initialized multi-controller style
  (``jax.distributed.initialize``), which pins ``process_index`` /
  ``process_count`` to the fleet identity and lets future cross-host
  collectives (ring-sharded datasets over the fleet) reuse the same
  bootstrap.  CPU test fleets run this for real: 2 processes x 4 forced
  host devices (``--xla_force_host_platform_device_count=4``) is the CI
  cluster-suite configuration.

``jax.distributed`` is OPTIONAL: transport-only fleets (the load
generator's ``--cluster-procs`` mode) skip it and take identity from the
explicit config, falling back to ``AIDW_CLUSTER_*`` environment variables —
the control plane (``repro.serving.cluster.rpc``) is plain sockets either
way.
"""

from __future__ import annotations

import os
from dataclasses import dataclass

__all__ = ["ClusterConfig", "ClusterContext", "bootstrap", "local_mesh"]


@dataclass(frozen=True)
class ClusterConfig:
    """Fleet identity + bootstrap knobs for ONE host process.

    ``jax_coordinator`` (``host:port``) turns on ``jax.distributed``;
    ``control_port`` is the base TCP port for the serving control plane
    (host ``i`` listens on ``control_port + i``; see ``cluster.rpc``).
    """

    n_hosts: int = 1
    host_id: int = 0
    jax_coordinator: str | None = None
    control_host: str = "127.0.0.1"
    control_port: int = 29900
    mesh_axis: str = "q"
    use_local_mesh: bool = True       # serve across all local devices
    # persistent XLA compilation cache directory (None = disabled).  Fleet
    # host processes bootstrapped with the same directory SHARE one cache:
    # the first host compiles, every later join deserializes.
    cache_dir: str | None = None

    @classmethod
    def from_env(cls, **overrides) -> "ClusterConfig":
        """Identity from ``AIDW_CLUSTER_{N_HOSTS,HOST_ID,JAX_COORDINATOR,
        CONTROL_HOST,CONTROL_PORT}`` env vars (plus ``AIDW_CACHE_DIR`` for
        the shared compilation cache), overridable by kwargs."""
        env = {
            "n_hosts": int(os.environ.get("AIDW_CLUSTER_N_HOSTS", "1")),
            "host_id": int(os.environ.get("AIDW_CLUSTER_HOST_ID", "0")),
            "jax_coordinator":
                os.environ.get("AIDW_CLUSTER_JAX_COORDINATOR") or None,
            "control_host":
                os.environ.get("AIDW_CLUSTER_CONTROL_HOST", "127.0.0.1"),
            "control_port":
                int(os.environ.get("AIDW_CLUSTER_CONTROL_PORT", "29900")),
            "cache_dir": os.environ.get("AIDW_CACHE_DIR") or None,
        }
        env.update(overrides)
        return cls(**env)

    def control_address(self, host_id: int) -> tuple[str, int]:
        return self.control_host, self.control_port + int(host_id)


@dataclass
class ClusterContext:
    """What :func:`bootstrap` hands the rest of the cluster stack."""

    cfg: ClusterConfig
    host_id: int
    n_hosts: int
    mesh: object | None               # LOCAL mesh (None = single device)
    jax_distributed: bool             # jax.distributed.initialize succeeded

    @property
    def is_coordinator(self) -> bool:
        return self.host_id == 0

    def shutdown(self) -> None:
        """Deregister from ``jax.distributed`` (no-op otherwise).

        The coordination service runs a fleet-wide SHUTDOWN BARRIER: every
        process must call this (the worker after its serve loop drains, the
        coordinator once it has closed its remote-host proxies) or the
        stragglers' processes are killed by the service's heartbeat-timeout
        error propagation.  Local jax stays usable afterwards.
        """
        if not self.jax_distributed:
            return
        import jax

        jax.distributed.shutdown()
        self.jax_distributed = False


def local_mesh(axis: str = "q"):
    """1-D mesh over this process's LOCAL devices (None if just one).

    Built from ``jax.local_devices()`` explicitly — ``jax.make_mesh``
    defaults to the GLOBAL device list, which under ``jax.distributed``
    would silently build a cross-process mesh the per-host data plane must
    not use.
    """
    import jax
    import numpy as np

    devs = jax.local_devices()
    if len(devs) <= 1:
        return None
    return jax.sharding.Mesh(np.asarray(devs), (axis,))


def bootstrap(cfg: ClusterConfig | None = None, **overrides) -> ClusterContext:
    """Initialize this process's fleet identity (idempotent per process).

    With ``cfg.jax_coordinator`` set and ``n_hosts > 1``, runs
    ``jax.distributed.initialize`` (all fleet processes must do so — it
    barriers on the coordinator) and cross-checks the fleet identity
    against ``jax.process_index``/``process_count``.  Without it, identity
    is taken from the config/env alone: the serving data plane never needs
    cross-process collectives, so a transport-only fleet is fully
    functional.
    """
    if cfg is None:
        cfg = ClusterConfig.from_env(**overrides)
    elif overrides:
        raise ValueError("pass either a ClusterConfig or overrides, not both")
    if not (0 <= cfg.host_id < cfg.n_hosts):
        raise ValueError(
            f"host_id {cfg.host_id} out of range for n_hosts={cfg.n_hosts}")

    # persistent compilation cache BEFORE any compile: subprocess fleet
    # hosts bootstrapped with the same directory (flag or AIDW_CACHE_DIR)
    # share one cache, so a joining host deserializes the ladder the first
    # host compiled.  Also installs the compile-event listeners that feed
    # the per-host compile_cache_hits/misses counters.
    from ...runtime import compile_cache
    compile_cache.enable(cfg.cache_dir)

    import jax

    distributed = False
    if cfg.n_hosts > 1 and cfg.jax_coordinator:
        try:
            jax.distributed.initialize(
                coordinator_address=cfg.jax_coordinator,
                num_processes=cfg.n_hosts, process_id=cfg.host_id)
            distributed = True
        except RuntimeError:
            # already initialized (bootstrap called twice in-process): keep
            # going with the existing runtime rather than failing the host
            distributed = jax.process_count() == cfg.n_hosts
        if distributed and (jax.process_index() != cfg.host_id
                            or jax.process_count() != cfg.n_hosts):
            raise RuntimeError(
                f"fleet identity mismatch: config says host "
                f"{cfg.host_id}/{cfg.n_hosts}, jax.distributed says "
                f"{jax.process_index()}/{jax.process_count()}")

    mesh = local_mesh(cfg.mesh_axis) if cfg.use_local_mesh else None
    return ClusterContext(cfg=cfg, host_id=cfg.host_id, n_hosts=cfg.n_hosts,
                          mesh=mesh, jax_distributed=distributed)
