"""Epoch-numbered dataset-update protocol for the multi-host serving fleet.

The single-process :class:`repro.serving.server.AsyncAidwServer` already
serializes dataset updates against query batches: an update is a FIFO
barrier through the one admission queue its worker drains, so churn can
never race a batch and every request is served against a well-defined
dataset state.  A fleet of host processes has no shared queue, so that
invariant is reconstructed from two pieces:

1. **Epoch assignment** — every ``update_dataset`` reaching the cluster is
   assigned a monotonically increasing epoch number by the ONE
   :class:`EpochCoordinator` (under its lock, so concurrent update calls
   serialize into a total order).  The coordinator also broadcast-enqueues
   the update to every live host *while still holding the lock*: each
   host's admission queue therefore receives updates in epoch order,
   interleaved at some point with that host's query stream.
2. **Ordered application** — each host's :class:`EpochApplier` admits
   updates to the local server strictly in epoch order: the next expected
   epoch is enqueued immediately, later epochs are buffered until the gap
   fills (transport reordering), and already-applied epochs are dropped
   idempotently (coordinator retries after a partial broadcast).

Consistency contract (also documented on ``repro.serving.cluster``): every
host applies the same updates in the same epoch order, and on each host an
update is a FIFO barrier between query batches.  A query routed to any host
is therefore served against dataset epoch ``k`` for some ``k`` that is (a)
a prefix of the global update order, identical across hosts, and (b) at
least the newest epoch whose broadcast completed before the query was
routed.  Results are bit-identical to a single ``AsyncAidwServer`` that
applied epochs ``1..k`` in order — which is what the cluster tests assert.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field

__all__ = ["EpochUpdate", "EpochCoordinator", "EpochApplier", "UpdateHandle"]


@dataclass
class EpochUpdate:
    """One dataset update with its fleet-assigned epoch number.

    Exactly like the server's update surface: either a full ``points_xyz``
    refresh, an incremental ``inserts``/``deletes`` delta, or (with
    ``compact=True``) a fleet-wide COMPACTION epoch that folds every host's
    LSM hot ring into its slab CSR (``repro.core.slab`` module docstring).
    Compactions consume an epoch like any other update, so a single server
    replaying the coordinator log replays them at the same points in the
    total order.

    ``trace_id``/``parent_span`` carry the coordinator's trace context
    (``repro.obs``) through the broadcast: every host records its local
    apply as an ``apply_epoch`` span under them, so one fleet update
    renders as one connected cross-host trace.
    """

    epoch: int
    points_xyz: object = None
    inserts: object = None
    deletes: object = None
    compact: bool = False
    trace_id: str | None = None
    parent_span: str | None = None

    @property
    def is_delta(self) -> bool:
        return self.points_xyz is None


class EpochCoordinator:
    """Assigns the fleet-wide total order of dataset updates.

    ``assign`` hands out epochs ``start+1, start+2, ...`` under a lock and
    records every update in ``log`` (epoch order), which is both the replay
    source for the single-server equivalence tests and the catch-up source
    for a host that joins or recovers mid-stream.
    """

    def __init__(self, start: int = 0):
        self._epoch = int(start)
        self._lock = threading.Lock()
        self.log: list[EpochUpdate] = []

    @property
    def epoch(self) -> int:
        """Newest assigned epoch (0 = construction-time dataset)."""
        with self._lock:
            return self._epoch

    def assign(self, *, points_xyz=None, inserts=None,
               deletes=None, compact=False, trace_id=None,
               parent_span=None) -> EpochUpdate:
        """Stamp the next epoch onto an update and log it."""
        with self._lock:
            self._epoch += 1
            upd = EpochUpdate(epoch=self._epoch, points_xyz=points_xyz,
                              inserts=inserts, deletes=deletes,
                              compact=compact, trace_id=trace_id,
                              parent_span=parent_span)
            self.log.append(upd)
            return upd

    def since(self, epoch: int) -> list[EpochUpdate]:
        """Updates newer than ``epoch``, in order (host catch-up)."""
        with self._lock:
            return [u for u in self.log if u.epoch > epoch]


class UpdateHandle:
    """Per-host handle for one offered update.

    Resolves in two stages: ``bound`` once the update was actually enqueued
    into the host server (immediately for in-order arrivals, later for
    buffered ones), then the underlying server op's ``applied`` event.
    Duplicates resolve immediately with ``duplicate=True``.
    """

    def __init__(self, epoch: int):
        self.epoch = epoch
        self.duplicate = False
        self.op = None                       # server _UpdateOp once bound
        self.error: BaseException | None = None
        self._bound = threading.Event()

    def _bind(self, op) -> None:
        self.op = op
        self._bound.set()

    def _fail(self, err: BaseException) -> None:
        self.error = err
        self._bound.set()

    def wait_bound(self, timeout: float | None = None) -> bool:
        return self._bound.wait(timeout)


class EpochApplier:
    """Strictly-ordered update admission for ONE host.

    ``enqueue`` is the host's non-blocking update hook (normally
    ``AsyncAidwServer.submit_update`` partial-applied with the update's
    payload); ``offer`` calls it exactly once per fresh epoch, in epoch
    order, buffering early arrivals until the gap fills.  Thread-safe.
    """

    def __init__(self, enqueue, *, applied_epoch: int = 0):
        self._enqueue = enqueue              # fn(EpochUpdate) -> server op
        self._next = int(applied_epoch) + 1
        self._buffer: dict[int, tuple[EpochUpdate, UpdateHandle]] = {}
        self._lock = threading.Lock()
        self.counters = {"enqueued": 0, "buffered": 0, "duplicates": 0}

    @property
    def next_epoch(self) -> int:
        with self._lock:
            return self._next

    def offer(self, update: EpochUpdate) -> UpdateHandle:
        """Admit ``update`` in epoch order; returns its :class:`UpdateHandle`.

        In-order updates bind (enqueue) before ``offer`` returns; early ones
        bind when their predecessors arrive; stale epochs are dropped as
        idempotent duplicates.
        """
        handle = UpdateHandle(update.epoch)
        with self._lock:
            if update.epoch < self._next:
                self.counters["duplicates"] += 1
                handle.duplicate = True
                handle._bound.set()
                return handle
            if update.epoch in self._buffer:
                self.counters["duplicates"] += 1
                handle.duplicate = True
                handle._bound.set()
                return handle
            self._buffer[update.epoch] = (update, handle)
            if update.epoch != self._next:
                self.counters["buffered"] += 1
            self._drain_locked()
        return handle

    def _drain_locked(self) -> None:
        while self._next in self._buffer:
            upd, handle = self._buffer.pop(self._next)
            try:
                handle._bind(self._enqueue(upd))
                self.counters["enqueued"] += 1
            except BaseException as e:
                # enqueue failed (server closed/crashed): resolve the handle
                # so the coordinator's wait sees the failure, and stop —
                # later epochs must not jump the dead one
                handle._fail(e)
                return
            self._next += 1
